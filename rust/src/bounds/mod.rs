//! The cosine triangle inequality (Schubert 2021) and the bound-maintenance
//! algebra built on it — the mathematical core of the paper.
//!
//! For unit vectors and `sim(x,y) = ⟨x,y⟩ = cos θ(x,y)`:
//!
//! ```text
//! sim(x,y) ≥ sim(x,z)·sim(z,y) − √((1−sim(x,z)²)(1−sim(z,y)²))   (Eq. 4)
//! sim(x,y) ≤ sim(x,z)·sim(z,y) + √((1−sim(x,z)²)(1−sim(z,y)²))   (Eq. 5)
//! ```
//!
//! These equal `cos(θxz + θzy)` and `cos(θxz − θzy)` — the arc-length
//! triangle inequality (Eq. 3) without trigonometric function calls.
//!
//! All similarities are clamped into `[-1, 1]` before entering `√(1−s²)`;
//! accumulated floating-point error can otherwise push `s²` above 1 and
//! poison the bound with NaN.

pub mod cc;
pub mod hamerly_bound;

/// Clamp a similarity into the valid cosine range `[-1, 1]`.
#[inline(always)]
pub fn clamp_sim(s: f64) -> f64 {
    s.clamp(-1.0, 1.0)
}

/// `sin θ` from `cos θ`: `√(1 − s²)`, safe under clamping.
#[inline(always)]
pub fn sin_from_cos(s: f64) -> f64 {
    let s = clamp_sim(s);
    (1.0 - s * s).max(0.0).sqrt()
}

/// Lower bound on `sim(x,y)` given `sim(x,z)` and `sim(z,y)` (Eq. 4),
/// i.e. `cos(θxz + θzy)` computed without trigonometric calls.
#[inline(always)]
pub fn sim_lower(sxz: f64, szy: f64) -> f64 {
    let (a, b) = (clamp_sim(sxz), clamp_sim(szy));
    clamp_sim(a * b - sin_from_cos(a) * sin_from_cos(b))
}

/// Upper bound on `sim(x,y)` given `sim(x,z)` and `sim(z,y)` (Eq. 5),
/// i.e. `cos(θxz − θzy)`.
#[inline(always)]
pub fn sim_upper(sxz: f64, szy: f64) -> f64 {
    let (a, b) = (clamp_sim(sxz), clamp_sim(szy));
    clamp_sim(a * b + sin_from_cos(a) * sin_from_cos(b))
}

/// Reference implementation of Eq. 3 via `arccos`/`cos` — used only in
/// tests and the `bench_bounds` ablation (it costs 60–100 cycles per trig
/// call, which is exactly why the paper avoids it).
pub fn sim_lower_arc(sxz: f64, szy: f64) -> f64 {
    (clamp_sim(sxz).acos() + clamp_sim(szy).acos()).cos()
}

/// Reference upper bound via arcs: `cos(|θxz − θzy|)`.
pub fn sim_upper_arc(sxz: f64, szy: f64) -> f64 {
    ((clamp_sim(sxz).acos() - clamp_sim(szy).acos()).abs()).cos()
}

/// Update the **lower** bound `l(i)` on the similarity to the own center
/// after that center moved with self-similarity `p = ⟨c, c'⟩` (Eq. 6):
/// `l ← l·p − √((1−l²)(1−p²))`.
///
/// **Saturation guard.** Eq. 6 as printed plugs the *bound* `l` into the
/// three-point inequality, but `cos(θ_l + θ_p)` is only a valid lower
/// bound while `θ_l + θ_p ≤ π`. If the center moved further than that
/// (`p ≤ −l`), no information remains and the bound must saturate to −1;
/// the unguarded formula would wrap around the sphere and *overestimate*.
/// The paper does not spell this out (with tightened bounds and small
/// center movements the guard almost never fires — but "almost" breaks
/// exactness; see `bounds::tests::chained_updates_remain_valid_bounds`).
#[inline(always)]
pub fn update_lower(l: f64, p: f64) -> f64 {
    if p <= -l {
        return -1.0;
    }
    sim_lower(l, p)
}

/// Update an **upper** bound `u(i,j)` on the similarity to another center
/// after it moved with self-similarity `p = ⟨c, c'⟩` (Eq. 7):
/// `u ← u·p + √((1−u²)(1−p²))`.
///
/// **Saturation guard** (mirror of [`update_lower`]): the unguarded
/// formula equals `cos(θ_u − θ_p)`, valid only while `θ_p ≤ θ_u`. If the
/// center moved further than the bound angle (`p ≤ u`), the true
/// similarity can reach 1 and the bound must saturate.
#[inline(always)]
pub fn update_upper(u: f64, p: f64) -> f64 {
    if p <= u {
        return 1.0;
    }
    sim_upper(u, p)
}

/// [`update_lower`] with the center's `sin θ_p = √(1−p²)` precomputed —
/// the Elkan variants update `N·k` bounds per iteration with only `k`
/// distinct `p(j)` values, so caching the sine halves the sqrt count
/// (§Perf optimization; see EXPERIMENTS.md).
#[inline(always)]
pub fn update_lower_pre(l: f64, p: f64, sin_p: f64) -> f64 {
    if p <= -l {
        return -1.0;
    }
    let l = clamp_sim(l);
    clamp_sim(l * p - sin_from_cos(l) * sin_p)
}

/// [`update_upper`] with the center's `sin θ_p` precomputed.
#[inline(always)]
pub fn update_upper_pre(u: f64, p: f64, sin_p: f64) -> f64 {
    if p <= u {
        return 1.0;
    }
    let u = clamp_sim(u);
    clamp_sim(u * p + sin_from_cos(u) * sin_p)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::forall;

    fn dot(a: &[f64], b: &[f64]) -> f64 {
        a.iter().zip(b).map(|(x, y)| x * y).sum()
    }

    #[test]
    fn triangle_inequality_holds_for_random_unit_vectors() {
        forall(500, 0x7121, |g| {
            let d = g.usize_in(2, 40);
            let x = g.unit(d);
            let y = g.unit(d);
            let z = g.unit(d);
            let sxy = dot(&x, &y);
            let sxz = dot(&x, &z);
            let szy = dot(&z, &y);
            let lo = sim_lower(sxz, szy);
            let hi = sim_upper(sxz, szy);
            assert!(
                sxy >= lo - 1e-9,
                "lower bound violated: sim={sxy}, bound={lo}"
            );
            assert!(
                sxy <= hi + 1e-9,
                "upper bound violated: sim={sxy}, bound={hi}"
            );
        });
    }

    #[test]
    fn closed_form_matches_trigonometric_form() {
        forall(500, 0x7122, |g| {
            let a = g.sim();
            let b = g.sim();
            assert!(
                (sim_lower(a, b) - sim_lower_arc(a, b)).abs() < 1e-9,
                "Eq.4 vs arc mismatch at ({a}, {b})"
            );
            assert!(
                (sim_upper(a, b) - sim_upper_arc(a, b)).abs() < 1e-9,
                "Eq.5 vs arc mismatch at ({a}, {b})"
            );
        });
    }

    #[test]
    fn bounds_are_ordered_and_in_range() {
        forall(500, 0x7123, |g| {
            let a = g.sim();
            let b = g.sim();
            let lo = sim_lower(a, b);
            let hi = sim_upper(a, b);
            assert!(lo <= hi + 1e-15);
            assert!((-1.0..=1.0).contains(&lo));
            assert!((-1.0..=1.0).contains(&hi));
        });
    }

    #[test]
    fn identity_center_does_not_move_bounds() {
        // p = 1 (center did not move) must leave bounds unchanged.
        forall(100, 0x7124, |g| {
            let l = g.sim();
            assert!((update_lower(l, 1.0) - l).abs() < 1e-12);
            assert!((update_upper(l, 1.0) - l).abs() < 1e-12);
        });
    }

    #[test]
    fn degenerate_inputs_are_safe() {
        // Values slightly outside [-1,1] (float error) must not NaN.
        for &(a, b) in &[
            (1.0 + 1e-9, 0.5),
            (-1.0 - 1e-9, 0.5),
            (1.0, 1.0),
            (-1.0, -1.0),
            (1.0, -1.0),
        ] {
            assert!(sim_lower(a, b).is_finite());
            assert!(sim_upper(a, b).is_finite());
        }
    }

    #[test]
    fn update_monotonically_widens_with_movement() {
        // More center movement (smaller p) must loosen bounds when the
        // current bound is high (the common case near convergence).
        let l = 0.9;
        let l1 = update_lower(l, 0.99);
        let l2 = update_lower(l, 0.90);
        assert!(l1 > l2, "smaller p should lower the lower bound");
        let u = 0.9;
        let u1 = update_upper(u, 0.99);
        let u2 = update_upper(u, 0.90);
        assert!(u1 < u2, "smaller p should raise the upper bound");
    }

    /// Random sparse unit vector: `nnz` active coordinates on a random
    /// pattern with Gaussian weights, normalized. Returned dense so the
    /// test-side reference dot stays trivial.
    fn sparse_unit(g: &mut crate::util::prop::Gen, d: usize, nnz: usize) -> Vec<f64> {
        loop {
            let pat = g.sparse_pattern(d, nnz.max(1));
            let mut v = vec![0.0f64; d];
            for &c in &pat {
                v[c] = g.rng().next_gaussian();
            }
            let n = dot(&v, &v).sqrt();
            if n > 1e-9 {
                for x in &mut v {
                    *x /= n;
                }
                return v;
            }
        }
    }

    #[test]
    fn triangle_inequality_holds_for_sparse_inputs() {
        // The engines run on sparse TF-IDF-like rows whose dots
        // concentrate on few shared coordinates — exercise the bounds in
        // that regime, not just on dense Gaussian directions.
        forall(300, 0x7126, |g| {
            let d = g.usize_in(8, 200);
            let x = sparse_unit(g, d, g.usize_in(1, d.min(12)));
            let y = sparse_unit(g, d, g.usize_in(1, d.min(12)));
            let z = sparse_unit(g, d, g.usize_in(1, d.min(12)));
            let (sxy, sxz, szy) = (dot(&x, &y), dot(&x, &z), dot(&z, &y));
            let lo = sim_lower(sxz, szy);
            let hi = sim_upper(sxz, szy);
            assert!(sxy >= lo - 1e-9, "lower bound violated: sim={sxy}, bound={lo}");
            assert!(sxy <= hi + 1e-9, "upper bound violated: sim={sxy}, bound={hi}");
        });
    }

    #[test]
    fn maintained_bounds_bracket_true_sims_across_k_centers() {
        // The Elkan/Hamerly maintenance loop in miniature: per-center
        // upper bounds and an own-center lower bound, carried through
        // Eq. 6/7 while every center drifts independently, must keep
        // bracketing the true cosines — for a singleton, a pair, and a
        // Yinyang-scale center set.
        for &k in &[1usize, 2, 64] {
            forall(40, 0x7127 ^ ((k as u64) << 8), |g| {
                let d = g.usize_in(4, 32);
                let x = sparse_unit(g, d, g.usize_in(1, d));
                let mut centers: Vec<Vec<f64>> = (0..k).map(|_| g.unit(d)).collect();
                let mut u: Vec<f64> = centers.iter().map(|c| dot(&x, c)).collect();
                let a = (0..k).fold(0, |b, j| if u[j] > u[b] { j } else { b });
                let mut l = u[a];
                for _ in 0..4 {
                    for (j, c) in centers.iter_mut().enumerate() {
                        let step = g.f64_in(0.0, 0.4);
                        let dir = g.unit(d);
                        let mut c2: Vec<f64> =
                            c.iter().zip(&dir).map(|(ci, di)| ci + step * di).collect();
                        let n = dot(&c2, &c2).sqrt();
                        for v in &mut c2 {
                            *v /= n;
                        }
                        let p = clamp_sim(dot(c, &c2));
                        u[j] = update_upper(u[j], p);
                        if j == a {
                            l = update_lower(l, p);
                        }
                        *c = c2;
                    }
                    for (j, c) in centers.iter().enumerate() {
                        let s = dot(&x, c);
                        assert!(
                            u[j] >= s - 1e-9,
                            "k={k}: u[{j}]={} below true sim {s}",
                            u[j]
                        );
                    }
                    let sa = dot(&x, &centers[a]);
                    assert!(l <= sa + 1e-9, "k={k}: l={l} above own-center sim {sa}");
                }
            });
        }
    }

    #[test]
    fn chained_updates_remain_valid_bounds() {
        // Simulate a center drifting over several iterations and check the
        // maintained bounds still bracket the true similarity.
        forall(200, 0x7125, |g| {
            let d = g.usize_in(2, 24);
            let x = g.unit(d);
            let mut c = g.unit(d);
            let mut l = dot(&x, &c);
            let mut u = dot(&x, &c);
            for _ in 0..5 {
                // Move the center a random small step and renormalize.
                let step = g.f64_in(0.0, 0.5);
                let dir = g.unit(d);
                let mut c2: Vec<f64> = c.iter().zip(&dir).map(|(a, b)| a + step * b).collect();
                let n = dot(&c2, &c2).sqrt();
                for v in &mut c2 {
                    *v /= n;
                }
                let p = clamp_sim(dot(&c, &c2));
                l = update_lower(l, p);
                u = update_upper(u, p);
                c = c2;
                let s = dot(&x, &c);
                assert!(l <= s + 1e-9, "lower bound {l} exceeds true sim {s}");
                assert!(u >= s - 1e-9, "upper bound {u} below true sim {s}");
            }
        });
    }
}
