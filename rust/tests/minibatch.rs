//! Integration tests for the mini-batch engine: seeded determinism across
//! thread counts, objective gap against the exact full-batch baseline on
//! synthetic blobs, and the truncated-centroid invariants — all through
//! the `SphericalKMeans` estimator with `Engine::MiniBatch`.

// Bench and test targets favour readable literal casts and exact
// (bit-level) float assertions; the workspace clippy warnings on
// those patterns are aimed at library code.
#![allow(clippy::cast_possible_truncation, clippy::float_cmp)]

use sphkm::data::synth::SynthConfig;
use sphkm::data::Dataset;
use sphkm::init::{seed_centers, InitMethod};
use sphkm::kmeans::{KMeansResult, Variant};
use sphkm::metrics;
use sphkm::sparse::DenseMatrix;
use sphkm::{Engine, MiniBatchParams, SphericalKMeans};

/// A blob corpus large enough for several row shards per batch and a
/// meaningful full-batch baseline.
fn blobs(n_docs: usize, seed: u64) -> Dataset {
    let mut cfg = SynthConfig::small_demo();
    cfg.name = "mb-blobs".into();
    cfg.n_docs = n_docs;
    cfg.topic_strength = 0.75;
    cfg.generate(seed)
}

/// Mini-batch estimator with the given engine params.
fn mb(k: usize, params: MiniBatchParams) -> SphericalKMeans {
    SphericalKMeans::new(k).engine(Engine::MiniBatch(params))
}

/// Fit from shared explicit centers, unwrapped to the result view.
fn fit_from(ds: &Dataset, centers: DenseMatrix, est: SphericalKMeans) -> KMeansResult {
    est.warm_start_centers(centers)
        .fit(&ds.matrix)
        .expect("test configuration is valid")
        .into_result()
}

#[test]
fn minibatch_is_deterministic_across_threads() {
    let ds = blobs(1500, 51);
    let k = 6;
    let init = seed_centers(&ds.matrix, k, &InitMethod::Uniform, 9);
    let params = MiniBatchParams { batch_size: 256, epochs: 4, ..Default::default() };
    let serial = fit_from(&ds, init.centers.clone(), mb(k, params).seed(13).threads(1));
    for &threads in &[4usize, 0] {
        let par = fit_from(&ds, init.centers.clone(), mb(k, params).seed(13).threads(threads));
        assert_eq!(
            par.assignments, serial.assignments,
            "assignments diverge at threads={threads}"
        );
        assert_eq!(
            par.objective.to_bits(),
            serial.objective.to_bits(),
            "objective not bit-identical at threads={threads}"
        );
        assert_eq!(par.iterations, serial.iterations);
        assert_eq!(par.converged, serial.converged);
        // Stats counters must not depend on scheduling either.
        assert_eq!(
            par.stats.total_point_center(),
            serial.stats.total_point_center()
        );
    }
}

#[test]
fn minibatch_is_reproducible_for_a_fixed_seed() {
    let ds = blobs(900, 53);
    let params = MiniBatchParams { batch_size: 128, epochs: 3, ..Default::default() };
    let a = mb(5, params).seed(7).fit(&ds.matrix).unwrap();
    let b = mb(5, params).seed(7).fit(&ds.matrix).unwrap();
    assert_eq!(a.assignments(), b.assignments());
    assert_eq!(a.objective().to_bits(), b.objective().to_bits());
    // A different seed draws different batches.
    let c = mb(5, params).seed(8).fit(&ds.matrix).unwrap();
    assert_ne!(
        a.assignments(),
        c.assignments(),
        "different seeds should explore different batch sequences"
    );
}

#[test]
fn minibatch_objective_is_close_to_full_batch() {
    let ds = blobs(2000, 57);
    let k = 8;
    let init = seed_centers(&ds.matrix, k, &InitMethod::Uniform, 5);
    let full = fit_from(
        &ds,
        init.centers.clone(),
        SphericalKMeans::new(k).variant(Variant::Standard),
    );
    let mbr = fit_from(
        &ds,
        init.centers.clone(),
        mb(k, MiniBatchParams { batch_size: 256, epochs: 8, tol: 1e-4, truncate: None }).seed(11),
    );
    let gap = metrics::objective_gap(mbr.objective, full.objective);
    // At this tiny scale the bar is looser than the bench's 2% (sampling
    // noise dominates); what matters is the order of magnitude.
    assert!(
        gap < 0.05,
        "mini-batch objective {:.2} more than 5% above full-batch {:.2} (gap {:.2}%)",
        mbr.objective,
        full.objective,
        gap * 100.0
    );
    // The seeded sampled evaluator agrees with the exact objective to
    // within its own sampling error.
    let est = metrics::objective_sampled(&ds.matrix, &mbr.assignments, &mbr.centers, 500, 3);
    assert!(
        (est - mbr.objective).abs() < 0.25 * mbr.objective.max(1.0),
        "sampled estimate {est} vs exact {}",
        mbr.objective
    );
}

#[test]
fn truncation_keeps_centers_unit_norm_and_sparse() {
    let ds = blobs(1200, 59);
    let k = 6;
    let m = 10;
    let params = MiniBatchParams {
        batch_size: 256,
        epochs: 4,
        truncate: Some(m),
        ..Default::default()
    };
    let r = mb(k, params).seed(17).fit(&ds.matrix).unwrap();
    for j in 0..k {
        let row = r.centers().row(j);
        let nnz = row.iter().filter(|&&v| v != 0.0).count();
        assert!(nnz <= m, "center {j} has {nnz} > {m} non-zeros");
        let norm: f64 = row.iter().map(|&v| (v as f64) * (v as f64)).sum();
        assert!(
            nnz == 0 || (norm - 1.0).abs() < 1e-4,
            "center {j} norm² = {norm}"
        );
    }
    // Truncated runs stay deterministic across thread counts too.
    let init = seed_centers(&ds.matrix, k, &InitMethod::Uniform, 21);
    let serial = fit_from(&ds, init.centers.clone(), mb(k, params).seed(17).threads(1));
    let par = fit_from(&ds, init.centers.clone(), mb(k, params).seed(17).threads(4));
    assert_eq!(serial.assignments, par.assignments);
    assert_eq!(serial.objective.to_bits(), par.objective.to_bits());
}

#[test]
fn minibatch_uses_fewer_similarities_than_full_batch_standard() {
    // On a corpus where Standard needs many iterations, the mini-batch
    // run's total point–center budget (epochs + the final pass) must come
    // in well under the full-batch total.
    let ds = blobs(2000, 61);
    let k = 8;
    let init = seed_centers(&ds.matrix, k, &InitMethod::Uniform, 23);
    let full = fit_from(
        &ds,
        init.centers.clone(),
        SphericalKMeans::new(k).variant(Variant::Standard),
    );
    let mbr = fit_from(
        &ds,
        init.centers.clone(),
        mb(k, MiniBatchParams { batch_size: 500, epochs: 2, tol: 0.0, truncate: None }).seed(3),
    );
    // 2 epochs + final pass = at most 3 corpus-worth of similarities
    // (exactly, since every batch charges k per point).
    let n = ds.matrix.rows() as u64;
    assert!(mbr.stats.total_point_center() <= 3 * n * k as u64);
    assert!(
        mbr.stats.total_point_center() < full.stats.total_point_center(),
        "mini-batch ({}) must undercut full batch ({})",
        mbr.stats.total_point_center(),
        full.stats.total_point_center()
    );
}
