//! Regenerates **Table 3** of the paper: run times (ms) of Standard,
//! Elkan, Simplified Elkan, Hamerly, and Simplified Hamerly across the six
//! dataset analogues and the k grid.
//!
//! ```text
//! cargo bench --bench bench_table3 -- [--scale tiny|small|medium]
//!     [--reps N] [--ks 2,10,20,50,100,200] [--quick] [--extended]
//!     [--runs N] [--warmup W]
//! ```
//!
//! `--runs` is honored as an alias for `--reps` (the uniform bench-suite
//! spelling) when `--reps` is absent; `--warmup W` runs W untimed tiny
//! passes before the measured experiment.
//!
//! `--extended` adds the Yinyang variant (§5.5, implemented beyond the
//! paper). `--table1` prints the dataset inventory as well.

// Bench and test targets favour readable literal casts and exact
// (bit-level) float assertions; the workspace clippy warnings on
// those patterns are aimed at library code.
#![allow(clippy::cast_possible_truncation, clippy::float_cmp)]

use sphkm::coordinator::experiments::{self, ExperimentOpts};
use sphkm::data::datasets::Scale;
use sphkm::util::cli::Args;

fn main() {
    let args = Args::from_env();
    let mut opts = ExperimentOpts::from_args(&args);
    if args.has("runs") && !args.has("reps") {
        opts.reps = args.get_or("runs", opts.reps).unwrap_or(opts.reps).max(1);
    }
    let warmup: usize = args.get_or("warmup", 0).unwrap_or(0);
    for _ in 0..warmup {
        println!("# warmup pass (untimed)");
        let mut w = opts.clone();
        w.scale = Scale::Tiny;
        w.reps = 1;
        w.ks = vec![2];
        experiments::table3(&w, false);
    }
    println!("# Table 3 bench — scale={}, reps={}", opts.scale.name(), opts.reps);
    if args.flag("table1") {
        experiments::table1(&opts);
    }
    experiments::table3(&opts, args.flag("extended"));
}
