//! Clean-run certification of the audit layer (`--features audit`): an
//! unmutated tree must pass fully audited fits of every engine — all
//! seven exact variants, the mini-batch optimizer (dense and truncated
//! centers), and the MaxScore-pruned serve traversal — with zero
//! violations. The mutation half of the contract (loosening any engine's
//! bound maintenance by 1e-3 makes these same runs fail with a
//! contextful `AuditViolation`) is what the checks in `sphkm::audit`
//! exist to catch; this suite pins the false-positive rate at zero.

#![cfg(feature = "audit")]

// Bench and test targets favour readable literal casts and exact
// (bit-level) float assertions; the workspace clippy warnings on
// those patterns are aimed at library code.
#![allow(clippy::cast_possible_truncation, clippy::float_cmp)]

use std::ops::ControlFlow;

use sphkm::data::datasets::{self, Scale};
use sphkm::data::synth::SynthConfig;
use sphkm::init::{seed_centers, InitMethod};
use sphkm::kmeans::{KernelChoice, Variant};
use sphkm::serve::ServeMode;
use sphkm::{Engine, ExactParams, IterSnapshot, MiniBatchParams, SphericalKMeans};

const VARIANTS: [Variant; 7] = [
    Variant::Standard,
    Variant::Elkan,
    Variant::SimplifiedElkan,
    Variant::Hamerly,
    Variant::SimplifiedHamerly,
    Variant::Yinyang,
    Variant::Exponion,
];

#[test]
fn audited_runs_of_all_exact_variants_are_clean() {
    for gen_seed in [3u64, 17] {
        let ds = SynthConfig::small_demo().generate(gen_seed);
        for k in [2usize, 8] {
            let init = seed_centers(&ds.matrix, k, &InitMethod::Uniform, 7);
            for variant in VARIANTS {
                let fitted = SphericalKMeans::new(k)
                    .variant(variant)
                    .warm_start_centers(init.centers.clone())
                    .fit(&ds.matrix);
                assert!(
                    fitted.is_ok(),
                    "{} (k={k}, gen {gen_seed}) audited run failed: {}",
                    variant.name(),
                    fitted.unwrap_err()
                );
            }
        }
    }
}

#[test]
fn audited_tight_bound_and_kernel_backends_are_clean() {
    // The guarded min-p single-bound update (Hamerly-bound family) and
    // every similarity-kernel backend take different code paths through
    // the same certified skip sites.
    let ds = datasets::newsgroups(Scale::Tiny, 5);
    for variant in [Variant::Hamerly, Variant::SimplifiedHamerly, Variant::Exponion] {
        let fitted = SphericalKMeans::new(6)
            .engine(Engine::Exact(ExactParams {
                variant,
                tight_bound: true,
                ..Default::default()
            }))
            .seed(11)
            .fit(&ds.matrix);
        assert!(
            fitted.is_ok(),
            "{} tight-bound audited run failed: {}",
            variant.name(),
            fitted.unwrap_err()
        );
    }
    for kernel in [
        KernelChoice::Dense,
        KernelChoice::Gather,
        KernelChoice::Inverted,
        KernelChoice::Pruned,
    ] {
        let fitted = SphericalKMeans::new(6)
            .variant(Variant::Elkan)
            .kernel(kernel)
            .seed(11)
            .fit(&ds.matrix);
        assert!(
            fitted.is_ok(),
            "elkan on {kernel:?} audited run failed: {}",
            fitted.unwrap_err()
        );
    }
    // Elkan only sends its initial pass through the pruned top-2 walk;
    // Standard and Hamerly drive it every iteration, so their audited
    // runs certify the threshold-seeded traversal (`audit_set_prune`
    // cross-checks each pruned training assignment exhaustively).
    for variant in [Variant::Standard, Variant::Hamerly] {
        let fitted = SphericalKMeans::new(6)
            .variant(variant)
            .kernel(KernelChoice::Pruned)
            .seed(11)
            .fit(&ds.matrix);
        assert!(
            fitted.is_ok(),
            "{} on the pruned kernel audited run failed: {}",
            variant.name(),
            fitted.unwrap_err()
        );
    }
}

#[test]
fn audited_minibatch_runs_are_clean() {
    let ds = SynthConfig::small_demo().generate(23);
    for truncate in [None, Some(8)] {
        let fitted = SphericalKMeans::new(5)
            .engine(Engine::MiniBatch(MiniBatchParams {
                batch_size: 64,
                epochs: 4,
                truncate,
                ..Default::default()
            }))
            .seed(3)
            .fit(&ds.matrix);
        assert!(
            fitted.is_ok(),
            "mini-batch (truncate {truncate:?}) audited run failed: {}",
            fitted.unwrap_err()
        );
    }
}

#[test]
fn audited_pruned_serve_matches_exhaustive() {
    // Under audit, every pruned query internally re-answers itself
    // exhaustively and panics on divergence — so simply driving the
    // pruned traversal over a query stream certifies it.
    let ds = datasets::newsgroups(Scale::Tiny, 5);
    let fitted = SphericalKMeans::new(8)
        .variant(Variant::SimplifiedElkan)
        .seed(2)
        .fit(&ds.matrix)
        .expect("audited training run is clean");
    let engine = fitted.query_engine(ServeMode::Pruned);
    let (top, stats) = engine.top_p_batch(&ds.matrix, 3);
    assert_eq!(top.len(), ds.matrix.rows());
    assert_eq!(stats.queries, ds.matrix.rows() as u64);
    // Single-query entry points run through the same certified path.
    let (one, _) = engine.top_p_pruned(ds.matrix.row(0), 2);
    assert_eq!(one.len(), 2);
}

#[test]
fn observer_sees_an_empty_violation_trail_on_clean_runs() {
    let ds = SynthConfig::small_demo().generate(9);
    let mut max_seen = usize::MAX;
    let mut obs = |s: &IterSnapshot<'_>| {
        max_seen = s.audit_violations.len();
        ControlFlow::Continue(())
    };
    let fitted = SphericalKMeans::new(4)
        .variant(Variant::Yinyang)
        .seed(5)
        .fit_observed(&ds.matrix, &mut obs)
        .expect("audited run is clean");
    assert_eq!(max_seen, 0, "clean run must record no violations");
    assert!(fitted.converged());
}
