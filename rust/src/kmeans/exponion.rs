//! Spherical Exponion (beyond the paper; §5.5 suggests the adaptation).
//!
//! Exponion (Newling & Fleuret 2016) keeps Hamerly's two bounds but, when
//! they fail, searches only the centers inside a ball around the assigned
//! center instead of all k. A center `j` can only beat the current
//! assignment if `d(c_a, c_j) < 2·d(x, c_a)`; on the sphere this becomes
//!
//! ```text
//! θ(c_a, c_j) < 2·θ(x, c_a)   ⇔   ⟨c_a, c_j⟩ > 2·l² − 1
//! ```
//!
//! (double-angle identity, `l = ⟨x, c_a⟩` tight). Each center keeps its
//! other centers **sorted by similarity descending**; the failing point
//! scans only the prefix above the threshold `2l² − 1`. The first
//! unscanned entry yields a valid upper bound for everything outside the
//! prefix via Eq. 5, which keeps the single bound `u` tight.
//!
//! Cost: the `O(k²)` center–center similarities per iteration (like full
//! Elkan/Hamerly) plus `O(k² log k)` sorting — traded against a much
//! smaller scan set than Hamerly's full re-scan. The neighbor lists are
//! rebuilt serially from the frozen centers; the per-point annulus scans
//! run on the sharded executor (see [`crate::kmeans`]).

use super::{
    audit_set_prune, bound_states, bound_works, Ctx, IterStats, KMeansConfig, Move, ShardOut,
    SimView,
};
use crate::audit::AUDIT_ENABLED;
use crate::bounds::hamerly_bound::{update_eq9_pre, update_min_p_guarded, update_safe};
use crate::bounds::{sim_upper, update_lower};
use crate::obs::{span::span_start, Phase};
use crate::util::timer::Stopwatch;

pub(crate) fn run(ctx: &mut Ctx<'_, '_>, cfg: &KMeansConfig) -> bool {
    let n = ctx.src.rows();
    let k = ctx.k;
    let mut l = vec![0.0f64; n];
    let mut u = vec![0.0f64; n];

    let stop = {
        let states = bound_states(&ctx.plan, &mut l, 1, &mut u, 1);
        ctx.initial_assignment(false, states, |(l, u), li, _bj, best, second, _| {
            l[li] = best;
            u[li] = second;
        })
    };
    if stop {
        return false;
    }
    ctx.stats.bound_bytes =
        2 * n * std::mem::size_of::<f64>() + k * (k - 1) * std::mem::size_of::<(f64, u32)>();

    // Per-center sorted neighbor lists: (similarity, center id) descending.
    let mut neighbors: Vec<Vec<(f64, u32)>> = vec![Vec::with_capacity(k - 1); k];
    let mut p_min_ex = vec![0.0f64; k];
    let mut p_max_ex = vec![0.0f64; k];
    let mut one_minus_pmin_sq = vec![0.0f64; k];

    for _ in 0..cfg.max_iter {
        let sw = Stopwatch::start();
        let mut iter = IterStats::default();
        let iteration = ctx.stats.iters.len();

        // Maintain-bound inputs across the last center movement (same
        // machinery as Hamerly §5.3).
        let sp = span_start();
        {
            let ex = ctx.centers.p_extremes();
            for a in 0..k {
                let pm = if k > 1 { ex.min_excluding(a) } else { 1.0 };
                p_min_ex[a] = pm;
                p_max_ex[a] = if k > 1 { ex.max_excluding(a) } else { 1.0 };
                one_minus_pmin_sq[a] = (1.0 - pm * pm).max(0.0);
            }
        }

        // Rebuild the sorted neighbor lists for the current centers.
        for list in &mut neighbors {
            list.clear();
        }
        for a in 0..k {
            for j in (a + 1)..k {
                let s = ctx.centers.centers().row_dot(a, ctx.centers.centers(), j);
                iter.sims_center_center += 1;
                neighbors[a].push((s, j as u32));
                neighbors[j].push((s, a as u32));
            }
        }
        for list in &mut neighbors {
            list.sort_unstable_by(|x, y| y.0.partial_cmp(&x.0).unwrap());
        }
        iter.phases.record(Phase::Bounds, sp);

        let sp = span_start();
        let outs = {
            let src = ctx.src;
            let centers = &ctx.centers;
            let p = ctx.centers.p();
            let tight = cfg.tight_hamerly_bound;
            let neighbors = &neighbors;
            let p_min_ex = &p_min_ex;
            let p_max_ex = &p_max_ex;
            let one_minus_pmin_sq = &one_minus_pmin_sq;
            let works = bound_works(&ctx.plan, &mut ctx.assign, &mut l, 1, &mut u, 1);
            ctx.pool.run(works, |_, (range, assign, l, u)| {
                let mut out = ShardOut::default();
                let mut view = SimView::new(src, centers, k);
                for (li, i) in range.enumerate() {
                    let a = assign[li] as usize;
                    // Maintain bounds across the last center movement.
                    l[li] = update_lower(l[li], p[a]);
                    u[li] = if tight {
                        update_min_p_guarded(u[li], p_min_ex[a])
                    } else if u[li] >= 0.0 && p_min_ex[a] >= 0.0 {
                        update_eq9_pre(u[li], one_minus_pmin_sq[a])
                    } else {
                        update_safe(u[li], p_min_ex[a], p_max_ex[a])
                    };
                    if l[li] >= u[li] {
                        out.iter.bound_skips += 1;
                        if AUDIT_ENABLED {
                            audit_set_prune(
                                &mut view,
                                &mut out.violations,
                                "exponion",
                                iteration,
                                i,
                                a,
                                0..k,
                                Some(u[li]),
                                Some(l[li]),
                            );
                        }
                        continue;
                    }
                    l[li] = view.similarity(i, a, &mut out.iter);
                    if l[li] >= u[li] {
                        out.iter.bound_skips += 1;
                        if AUDIT_ENABLED {
                            audit_set_prune(
                                &mut view,
                                &mut out.violations,
                                "exponion",
                                iteration,
                                i,
                                a,
                                0..k,
                                Some(u[li]),
                                Some(l[li]),
                            );
                        }
                        continue;
                    }
                    // Scan the annulus: neighbors of a with sim > 2l²−1.
                    let threshold = 2.0 * l[li] * l[li] - 1.0;
                    let mut m1 = f64::MIN;
                    let mut m2 = f64::MIN;
                    let mut jm = a;
                    let mut outside = -1.0f64; // sim(ca, c_first-unscanned)
                    let mut scanned_all = true;
                    let mut prefix = 0usize; // neighbors scanned before the cut
                    for &(s_aj, j) in &neighbors[a] {
                        // Only prune by the annulus when l ≥ 0 (the
                        // double-angle threshold needs 2θ ≤ 2π guarded by
                        // cos monotonicity; for l < 0 scan everything —
                        // rare and still exact).
                        if l[li] >= 0.0 && s_aj <= threshold {
                            outside = s_aj;
                            scanned_all = false;
                            break;
                        }
                        let s = view.similarity(i, j as usize, &mut out.iter);
                        prefix += 1;
                        if s > m1 {
                            m2 = m1;
                            m1 = s;
                            jm = j as usize;
                        } else if s > m2 {
                            m2 = s;
                        }
                    }
                    // Upper bound for everything outside the scanned
                    // prefix.
                    let outside_bound = if scanned_all {
                        f64::MIN
                    } else {
                        sim_upper(outside, l[li])
                    };
                    if AUDIT_ENABLED && !scanned_all {
                        // The unscanned tail was pruned by the annulus
                        // test; outside_bound (Eq. 5 on the first
                        // unscanned neighbor) is its shared upper bound.
                        // l(i) is exact here, so no lower check is needed.
                        audit_set_prune(
                            &mut view,
                            &mut out.violations,
                            "exponion",
                            iteration,
                            i,
                            a,
                            neighbors[a][prefix..].iter().map(|&(_, j)| j as usize),
                            Some(outside_bound),
                            None,
                        );
                    }
                    if m1 > l[li] {
                        // Reassign. Others now include the old center
                        // (tight l_old) and the unscanned tail
                        // (≤ outside_bound).
                        let l_old = l[li];
                        assign[li] = jm as u32;
                        out.moves.push(Move { i: i as u32, from: a as u32, to: jm as u32 });
                        out.iter.reassignments += 1;
                        u[li] = m2.max(l_old).max(outside_bound).max(-1.0);
                        l[li] = m1;
                    } else {
                        u[li] = m1.max(outside_bound).max(-1.0);
                    }
                }
                out
            })
        };
        iter.phases.record(Phase::Assignment, sp);
        let sp = span_start();
        ctx.merge_shards(outs, &mut iter);

        if iter.reassignments == 0 {
            iter.phases.record(Phase::Update, sp);
            iter.wall_ms = sw.ms();
            ctx.push_iter(iter, true);
            return true;
        }
        iter.sims_center_center += ctx.centers.update();
        iter.phases.record(Phase::Update, sp);
        iter.phases
            .shift(Phase::Update, Phase::IndexRefresh, ctx.centers.take_refresh_ms());
        iter.wall_ms = sw.ms();
        if ctx.push_iter(iter, false) {
            return false;
        }
    }
    false
}
