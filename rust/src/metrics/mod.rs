//! Clustering-quality metrics: the spherical k-means objective plus
//! external validation against planted labels (NMI, ARI, purity) used by
//! the examples and the end-to-end driver.

mod silhouette;

pub use silhouette::silhouette_sampled;

use crate::sparse::{CsrMatrix, DenseMatrix};
use crate::util::rng::Xoshiro256;

/// The spherical k-means objective `Σᵢ (1 − ⟨xᵢ, c(a(i))⟩)` (lower is
/// better) for an arbitrary assignment/centers pair.
pub fn objective(data: &CsrMatrix, assign: &[u32], centers: &DenseMatrix) -> f64 {
    assert_eq!(assign.len(), data.rows());
    let mut obj = 0.0;
    for i in 0..data.rows() {
        obj += 1.0 - data.row(i).dot_dense(centers.row(assign[i] as usize));
    }
    obj
}

/// Seeded Monte-Carlo estimate of [`objective`] on a uniform sample of
/// `sample` distinct rows, scaled up to the full-corpus value. With
/// `sample ≥ rows` it computes the exact objective. Deterministic in
/// `seed`, so approximate engines (the mini-batch subsystem) can be
/// regression-tested on corpora where the exact `O(N)` evaluation is the
/// dominant cost.
pub fn objective_sampled(
    data: &CsrMatrix,
    assign: &[u32],
    centers: &DenseMatrix,
    sample: usize,
    seed: u64,
) -> f64 {
    assert_eq!(assign.len(), data.rows());
    let n = data.rows();
    if n == 0 {
        return 0.0;
    }
    if sample >= n {
        return objective(data, assign, centers);
    }
    let mut rng = Xoshiro256::seed_from_u64(seed);
    let rows = rng.sample_distinct(n, sample.max(1));
    let mut obj = 0.0;
    for &i in &rows {
        obj += 1.0 - data.row(i).dot_dense(centers.row(assign[i] as usize));
    }
    obj * n as f64 / rows.len() as f64
}

/// Relative objective gap of a candidate clustering against a reference
/// objective: `(candidate − reference) / reference`. Positive means the
/// candidate is worse (spherical k-means objectives decrease with
/// quality); a mini-batch run within the acceptance bar satisfies
/// `objective_gap(mb, full) ≤ 0.02`. Near-zero references (degenerate
/// perfect clusterings) fall back to the absolute difference so the gap
/// stays finite.
pub fn objective_gap(candidate: f64, reference: f64) -> f64 {
    if reference.abs() < 1e-12 {
        return candidate - reference;
    }
    (candidate - reference) / reference
}

/// Contingency table between two labelings.
fn contingency(a: &[u32], b: &[u32]) -> (Vec<Vec<u64>>, Vec<u64>, Vec<u64>) {
    assert_eq!(a.len(), b.len());
    let ka = a.iter().copied().max().map(|m| m as usize + 1).unwrap_or(0);
    let kb = b.iter().copied().max().map(|m| m as usize + 1).unwrap_or(0);
    let mut table = vec![vec![0u64; kb]; ka];
    let mut ra = vec![0u64; ka];
    let mut rb = vec![0u64; kb];
    for (&x, &y) in a.iter().zip(b) {
        table[x as usize][y as usize] += 1;
        ra[x as usize] += 1;
        rb[y as usize] += 1;
    }
    (table, ra, rb)
}

/// Normalized Mutual Information (arithmetic normalization), in `[0, 1]`.
pub fn nmi(a: &[u32], b: &[u32]) -> f64 {
    let n = a.len() as f64;
    if a.is_empty() {
        return 0.0;
    }
    let (table, ra, rb) = contingency(a, b);
    let mut mi = 0.0;
    for (i, row) in table.iter().enumerate() {
        for (j, &nij) in row.iter().enumerate() {
            if nij > 0 {
                let nij = nij as f64;
                mi += nij / n * ((n * nij) / (ra[i] as f64 * rb[j] as f64)).ln();
            }
        }
    }
    let h = |counts: &[u64]| -> f64 {
        counts
            .iter()
            .filter(|&&c| c > 0)
            .map(|&c| {
                let p = c as f64 / n;
                -p * p.ln()
            })
            .sum()
    };
    let (ha, hb) = (h(&ra), h(&rb));
    if ha == 0.0 && hb == 0.0 {
        return 1.0; // both labelings are constant ⇒ identical structure
    }
    let denom = 0.5 * (ha + hb);
    if denom == 0.0 {
        0.0
    } else {
        (mi / denom).clamp(0.0, 1.0)
    }
}

/// Adjusted Rand Index, in `[-1, 1]` (1 = identical partitions).
pub fn ari(a: &[u32], b: &[u32]) -> f64 {
    let n = a.len() as f64;
    if a.is_empty() {
        return 0.0;
    }
    let (table, ra, rb) = contingency(a, b);
    let c2 = |x: u64| -> f64 {
        let x = x as f64;
        x * (x - 1.0) / 2.0
    };
    let sum_ij: f64 = table.iter().flatten().map(|&v| c2(v)).sum();
    let sum_a: f64 = ra.iter().map(|&v| c2(v)).sum();
    let sum_b: f64 = rb.iter().map(|&v| c2(v)).sum();
    let total = c2(n as u64);
    let expected = sum_a * sum_b / total;
    let max_index = 0.5 * (sum_a + sum_b);
    if (max_index - expected).abs() < 1e-12 {
        return if (sum_ij - expected).abs() < 1e-12 { 1.0 } else { 0.0 };
    }
    (sum_ij - expected) / (max_index - expected)
}

/// Purity: fraction of points whose cluster's majority label matches theirs.
pub fn purity(pred: &[u32], truth: &[u32]) -> f64 {
    if pred.is_empty() {
        return 0.0;
    }
    let (table, _, _) = contingency(pred, truth);
    let correct: u64 = table
        .iter()
        .map(|row| row.iter().copied().max().unwrap_or(0))
        .sum();
    correct as f64 / pred.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identical_partitions_score_one() {
        let a = vec![0, 0, 1, 1, 2, 2];
        assert!((nmi(&a, &a) - 1.0).abs() < 1e-12);
        assert!((ari(&a, &a) - 1.0).abs() < 1e-12);
        assert!((purity(&a, &a) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn permuted_labels_still_score_one() {
        let a = vec![0, 0, 1, 1, 2, 2];
        let b = vec![2, 2, 0, 0, 1, 1];
        assert!((nmi(&a, &b) - 1.0).abs() < 1e-12);
        assert!((ari(&a, &b) - 1.0).abs() < 1e-12);
        assert!((purity(&a, &b) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn independent_partitions_score_low() {
        // Balanced 2×2 independence.
        let a = vec![0, 0, 1, 1, 0, 0, 1, 1];
        let b = vec![0, 1, 0, 1, 0, 1, 0, 1];
        assert!(nmi(&a, &b).abs() < 1e-9);
        assert!(ari(&a, &b).abs() < 0.26);
        assert!((purity(&a, &b) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn ari_known_value() {
        // sklearn doctest example: ARI([0,0,1,2],[0,0,1,1]) = 0.571428…
        let a = vec![0, 0, 1, 2];
        let b = vec![0, 0, 1, 1];
        assert!((ari(&a, &b) - 0.5714285714).abs() < 1e-9);
    }

    #[test]
    fn nmi_is_symmetric() {
        let a = vec![0, 0, 1, 1, 2, 2, 0, 1];
        let b = vec![1, 1, 0, 0, 2, 1, 0, 1];
        assert!((nmi(&a, &b) - nmi(&b, &a)).abs() < 1e-12);
    }

    #[test]
    fn objective_sampled_estimates_exact_value() {
        use crate::data::synth::SynthConfig;
        let ds = SynthConfig::small_demo().generate(31);
        let r = crate::kmeans::SphericalKMeans::new(6)
            .variant(crate::kmeans::Variant::Standard)
            .seed(3)
            .fit(&ds.matrix)
            .unwrap()
            .into_result();
        let exact = objective(&ds.matrix, &r.assignments, &r.centers);
        // sample ≥ rows: exact.
        let full = objective_sampled(&ds.matrix, &r.assignments, &r.centers, 10_000, 1);
        assert_eq!(full, exact);
        // Seeded: same seed, same estimate.
        let a = objective_sampled(&ds.matrix, &r.assignments, &r.centers, 100, 7);
        let b = objective_sampled(&ds.matrix, &r.assignments, &r.centers, 100, 7);
        assert_eq!(a, b);
        // A third of the corpus estimates within a loose relative band.
        assert!(
            (a - exact).abs() < 0.5 * exact.max(1.0),
            "estimate {a} too far from exact {exact}"
        );
    }

    #[test]
    fn objective_gap_signs_and_degenerate_reference() {
        assert!((objective_gap(102.0, 100.0) - 0.02).abs() < 1e-12);
        assert!((objective_gap(98.0, 100.0) + 0.02).abs() < 1e-12);
        assert_eq!(objective_gap(0.5, 0.0), 0.5, "absolute fallback");
    }

    #[test]
    fn objective_matches_manual() {
        use crate::sparse::SparseVec;
        let rows = vec![
            SparseVec::from_pairs(2, vec![(0, 1.0)]),
            SparseVec::from_pairs(2, vec![(1, 1.0)]),
        ];
        let m = CsrMatrix::from_rows(2, &rows);
        let centers = DenseMatrix::from_vec(1, 2, vec![std::f32::consts::FRAC_1_SQRT_2; 2]);
        let obj = objective(&m, &[0, 0], &centers);
        let expect = 2.0 * (1.0 - std::f64::consts::FRAC_1_SQRT_2);
        assert!((obj - expect).abs() < 1e-6);
    }
}
