//! Serving-daemon integration suite: the full train → persist → serve →
//! hot-swap pipeline, at the library level and through the `sphkm`
//! binary (`serve` / `query` subcommands), including the satellite
//! guarantee that CLI model-load failures exit 2 with a one-line typed
//! diagnostic.

// Bench and test targets favour readable literal casts and exact
// (bit-level) float assertions; the workspace clippy warnings on
// those patterns are aimed at library code.
#![allow(clippy::cast_possible_truncation, clippy::float_cmp)]

use std::path::PathBuf;
use std::process::Command;
use std::time::Duration;

use sphkm::data::datasets::{self, Scale};
use sphkm::kmeans::{Engine, FittedModel, MiniBatchParams, SphericalKMeans};
use sphkm::serve::{Client, Daemon, DaemonConfig, RefitConfig, ServeMode};

fn tmp(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("sphkm-daemon-int-tests-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(name)
}

fn sphkm() -> Command {
    Command::new(env!("CARGO_BIN_EXE_sphkm"))
}

/// Kills the daemon subprocess when a test panics mid-flight, so a
/// failing assertion never leaks a listener into the test runner.
struct KillOnDrop(std::process::Child);

impl Drop for KillOnDrop {
    fn drop(&mut self) {
        let _ = self.0.kill();
        let _ = self.0.wait();
    }
}

/// End-to-end over the binary: train and persist two models, serve one,
/// and drive `sphkm query` clients through a `reload` swap — the query
/// CSVs must be **byte-identical** to one-shot `assign --out` CSVs for
/// whichever model the serving epoch holds (the daemon-smoke CI job
/// replays this same sequence on an ephemeral port).
#[test]
fn serve_query_round_trip_matches_assign_bytes() {
    let a = tmp("cli-a.spkm");
    let b = tmp("cli-b.spkm");
    let data = ["--data", "demo", "--scale", "tiny", "--seed", "7"];
    for (path, k, init) in [(&a, "5", "uniform"), (&b, "4", "kmeans++")] {
        let out = sphkm()
            .args(data)
            .args(["cluster", "--k", k, "--init", init, "--save-model", path.to_str().unwrap()])
            .output()
            .expect("spawn cluster");
        assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    }
    // One-shot oracle CSVs.
    let a_csv = tmp("cli-a.csv");
    let b_csv = tmp("cli-b.csv");
    for (model, csv) in [(&a, &a_csv), (&b, &b_csv)] {
        let out = sphkm()
            .args(data)
            .args(["assign", "--top", "3", "--mode", "exhaustive", "--threads", "1"])
            .args(["--model", model.to_str().unwrap(), "--out", csv.to_str().unwrap()])
            .output()
            .expect("spawn assign");
        assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    }

    // Daemon on an ephemeral port, discovered through --addr-file.
    let addr_file = tmp("cli-addr.txt");
    std::fs::remove_file(&addr_file).ok();
    let child = sphkm()
        .args(["serve", "--model", a.to_str().unwrap(), "--addr", "127.0.0.1:0"])
        .args(["--addr-file", addr_file.to_str().unwrap()])
        .args(["--mode", "exhaustive", "--threads", "1"])
        .spawn()
        .expect("spawn serve");
    let mut child = KillOnDrop(child);
    let deadline = std::time::Instant::now() + Duration::from_secs(60);
    while !addr_file.exists() {
        assert!(std::time::Instant::now() < deadline, "daemon never wrote its address");
        assert!(
            child.0.try_wait().expect("try_wait").is_none(),
            "daemon exited before binding"
        );
        std::thread::sleep(Duration::from_millis(20));
    }
    let addr_args = ["--addr-file", addr_file.to_str().unwrap()];

    // Query → byte-identical to the model-A oracle; reload to B; repeat.
    let q_csv = tmp("cli-q.csv");
    let query = |out_csv: &PathBuf| {
        let out = sphkm()
            .args(data)
            .args(["query", "--top", "3", "--out", out_csv.to_str().unwrap()])
            .args(addr_args)
            .output()
            .expect("spawn query");
        assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    };
    query(&q_csv);
    assert_eq!(
        std::fs::read(&q_csv).unwrap(),
        std::fs::read(&a_csv).unwrap(),
        "epoch 0 answers must be byte-identical to one-shot assign on model A"
    );
    let out = sphkm()
        .args(["query", "--op", "reload", "--path", b.to_str().unwrap()])
        .args(addr_args)
        .output()
        .expect("spawn reload");
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    query(&q_csv);
    assert_eq!(
        std::fs::read(&q_csv).unwrap(),
        std::fs::read(&b_csv).unwrap(),
        "post-swap answers must be byte-identical to one-shot assign on model B"
    );

    // Stats over the CLI, then an orderly shutdown.
    let out = sphkm().args(["query", "--op", "stats"]).args(addr_args).output().expect("stats");
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("1 hot swaps"), "{text}");
    let out = sphkm()
        .args(["query", "--op", "shutdown"])
        .args(addr_args)
        .output()
        .expect("shutdown");
    assert!(out.status.success());
    let deadline = std::time::Instant::now() + Duration::from_secs(30);
    loop {
        if let Some(status) = child.0.try_wait().expect("try_wait") {
            assert!(status.success(), "daemon exit status after shutdown RPC");
            break;
        }
        assert!(std::time::Instant::now() < deadline, "daemon ignored the shutdown RPC");
        std::thread::sleep(Duration::from_millis(50));
    }
}

/// Satellite: `assign`, `serve`, and `cluster --resume` report the typed
/// `ModelError` and exit 2 — a one-line diagnostic, no panic backtrace.
#[test]
fn model_load_failures_exit_2_with_typed_diagnostic() {
    let garbage = tmp("not-a-model.spkm");
    std::fs::write(&garbage, b"definitely not an spkm file").unwrap();
    let missing = tmp("never-written.spkm");
    std::fs::remove_file(&missing).ok();
    for (cmd, path) in [
        ("assign", &garbage),
        ("serve", &garbage),
        ("assign", &missing),
        ("serve", &missing),
    ] {
        let out = sphkm()
            .args([cmd, "--model", path.to_str().unwrap(), "--data", "demo", "--scale", "tiny"])
            .output()
            .expect("spawn");
        assert_eq!(out.status.code(), Some(2), "{cmd} {}", path.display());
        let err = String::from_utf8_lossy(&out.stderr);
        assert!(err.contains("error loading model"), "{cmd}: {err}");
        assert!(!err.contains("panicked"), "{cmd}: {err}");
    }
    let out = sphkm()
        .args(["cluster", "--data", "demo", "--scale", "tiny", "--k", "3"])
        .args(["--resume", garbage.to_str().unwrap()])
        .output()
        .expect("spawn");
    assert_eq!(out.status.code(), Some(2), "cluster --resume");
    assert!(String::from_utf8_lossy(&out.stderr).contains("error loading model"));
}

/// A `refit` RPC round is deterministic: warm-started from the live
/// lineage with the lineage's own seed, it must publish exactly the
/// model an offline warm-started fit of the same corpus produces.
#[test]
fn refit_round_is_bit_identical_to_offline_warm_start() {
    let ds = datasets::by_name("demo", Scale::Tiny, 11).expect("demo dataset");
    let params = MiniBatchParams { batch_size: 256, epochs: 2, ..Default::default() };
    let base = SphericalKMeans::new(4)
        .engine(Engine::MiniBatch(params))
        .seed(11)
        .threads(1)
        .fit(&ds.matrix)
        .expect("base fit");
    let model = base.to_model(); // carries the resumable training state

    // The offline continuation the daemon's refit round must reproduce.
    let expected = SphericalKMeans::new(4)
        .engine(Engine::MiniBatch(params))
        .seed(base.meta().seed)
        .threads(1)
        .warm_start(&FittedModel::from_model(model.clone()))
        .fit(&ds.matrix)
        .expect("offline warm-started fit");
    let oracle = expected.query_engine_with(ServeMode::Exhaustive, 1);

    let cfg = DaemonConfig {
        mode: ServeMode::Exhaustive,
        threads: 1,
        refit: Some(RefitConfig {
            data: ds.matrix.clone(),
            params,
            threads: 1,
            interval: None, // RPC-only
        }),
        ..DaemonConfig::default()
    };
    let handle = Daemon::start(model, &cfg).expect("daemon starts");
    let mut client = Client::connect(handle.local_addr()).expect("connect");
    assert_eq!(client.refit().expect("refit round"), 1, "refit publishes epoch 1");

    let probe_rows = ds.matrix.rows().min(64);
    let rows: Vec<(Vec<u32>, Vec<f32>)> = (0..probe_rows)
        .map(|i| {
            let r = ds.matrix.row(i);
            (r.indices.to_vec(), r.values.to_vec())
        })
        .collect();
    let (epoch, got) = client.query(2, &rows).expect("query");
    assert_eq!(epoch, 1);
    let probe = sphkm::sparse::CsrMatrix::from_rows(
        ds.matrix.cols(),
        &(0..probe_rows)
            .map(|i| {
                sphkm::sparse::SparseVec::from_pairs(
                    ds.matrix.cols(),
                    ds.matrix
                        .row(i)
                        .indices
                        .iter()
                        .zip(ds.matrix.row(i).values)
                        .map(|(&c, &v)| (c, v))
                        .collect(),
                )
            })
            .collect::<Vec<_>>(),
    );
    let (want, _) = oracle.top_p_batch(&probe, 2);
    assert_eq!(got.len(), want.len());
    for (i, (g, w)) in got.iter().zip(&want).enumerate() {
        assert_eq!(g.len(), w.len(), "row {i}");
        for (x, y) in g.iter().zip(w) {
            assert_eq!(x.0, y.0, "row {i}: center ids");
            assert_eq!(x.1.to_bits(), y.1.to_bits(), "row {i}: similarities");
        }
    }

    // A second round continues from the *refit* lineage, not the
    // original — epochs keep advancing.
    assert_eq!(client.refit().expect("second refit"), 2);
    client.shutdown().expect("shutdown");
    let metrics = handle.join();
    assert_eq!(metrics.counter("daemon.refits"), 2);
    assert_eq!(metrics.counter("daemon.errors"), 0);
}
