//! Regenerates **Fig. 2** of the paper: run time vs k on the DBLP
//! author-conference analogue (high N, low d) and its transpose
//! (low N, high d) — the contrast where the `O(k²·d)` center–center cost
//! makes the full Elkan/Hamerly variants blow up.
//!
//! ```text
//! cargo bench --bench bench_fig2 -- [--scale S] [--reps N] [--ks ...]
//!     [--ablation]   # adds the cc-cost-vs-dimensionality ablation
//! ```

// Bench and test targets favour readable literal casts and exact
// (bit-level) float assertions; the workspace clippy warnings on
// those patterns are aimed at library code.
#![allow(clippy::cast_possible_truncation, clippy::float_cmp)]

use sphkm::coordinator::experiments::{self, ExperimentOpts};
use sphkm::util::cli::Args;

fn main() {
    let args = Args::from_env();
    let opts = ExperimentOpts::from_args(&args);
    println!("# Fig. 2 bench — scale={}, reps={}", opts.scale.name(), opts.reps);
    experiments::fig2(&opts);
    if args.flag("ablation") {
        let k = args.get_or("k", 50usize).unwrap_or(50);
        experiments::ablation_cc(&opts, k);
    }
}
