//! Inverted-file (CSC-style) index over the cluster centers.
//!
//! The dense d×k transposed center matrix behind the default similarity
//! kernel costs `O(d·k)` memory and `O(nnz(row)·k)` multiply-adds per
//! all-centers pass — every (point, center) pair pays, even when the
//! center is zero in all of the point's terms. For document workloads the
//! sparse follow-up literature (Aoyama & Saito's SIVF, arXiv:2103.16141;
//! Knittel et al., arXiv:2108.00895) inverts the centers instead: per
//! dimension, a **postings list** of the centers with a non-zero there.
//! An all-centers similarity pass then walks only the postings of the
//! row's own terms, skipping every pair that shares no term.
//!
//! **Layout.** Each dimension's postings are stored structure-of-arrays
//! (a `centers: Vec<u32>` id stream next to a `values: Vec<f32>` weight
//! stream) per SIVF's structured-inverted-file layout: the accumulation
//! loop streams two homogeneous, cache-sequential arrays instead of
//! interleaved 8-byte records, which is where the postings walk spends
//! its time on sparse text.
//!
//! **Bit-exactness contract.** [`InvertedIndex::sims_into`] accumulates
//! per-center contributions in ascending dimension order of the row's
//! non-zeros — the same `f64` addition sequence the dense-transpose kernel
//! produces for that center, minus terms whose product is an exact ±0.0
//! (which cannot change a `+0.0`-initialized accumulator). Similarities
//! are therefore bit-identical to the dense kernel's, which is what lets
//! the two backends interchange under the exactness tests of
//! [`crate::kmeans`].
//!
//! Maintenance is incremental: [`InvertedIndex::refresh_center`] rewrites
//! only the postings of one (dirty) center, so an iteration that moves
//! few centers pays for few centers — the same dirty-flag discipline
//! [`crate::kmeans::Centers`] applies to its transpose columns. The
//! per-dimension **MaxScore bound table** `maxw[c] = max_j |centers[j][c]|`
//! is cached inside the index under the same discipline: a dirty center's
//! refresh recomputes only the dimensions in its old ∪ new support, so
//! serving batches and the pruned training kernel read it for free
//! instead of paying a full `O(nnz)` scan.

use super::csr::RowView;
use super::dense::DenseMatrix;
use crate::audit::AuditViolation;

/// One dimension's postings: the centers with a non-zero coordinate
/// there, sorted by center id ascending, stored structure-of-arrays
/// (SIVF-style) so the accumulation loop streams homogeneous arrays.
#[derive(Debug, Clone, Default)]
struct PostingList {
    /// Center ids, ascending.
    centers: Vec<u32>,
    /// The centers' values at this dimension, parallel to `centers`.
    values: Vec<f32>,
}

impl PostingList {
    #[inline]
    fn len(&self) -> usize {
        self.centers.len()
    }

    /// Recompute this list's maximum absolute weight from scratch.
    #[inline]
    fn max_abs(&self) -> f32 {
        self.values.iter().map(|v| v.abs()).fold(0.0f32, f32::max)
    }
}

/// CSC-style inverted file over a k×d centers matrix: for each dimension,
/// the centers with a non-zero coordinate there, sorted by center id.
#[derive(Debug, Clone)]
pub struct InvertedIndex {
    k: usize,
    /// Per-dimension postings, each sorted by center id ascending.
    postings: Vec<PostingList>,
    /// Per-center sorted list of dimensions where the center is non-zero
    /// (its support) — what `refresh_center` must erase before rewriting.
    support: Vec<Vec<u32>>,
    /// Cached per-dimension MaxScore bound table:
    /// `maxw[c] = max_j |centers[j][c]|`, maintained incrementally per
    /// dirty center alongside the postings themselves.
    maxw: Vec<f32>,
    /// Total postings across all dimensions.
    nnz: usize,
}

impl InvertedIndex {
    /// Empty index for `k` centers over `d` dimensions.
    pub fn new(d: usize, k: usize) -> Self {
        Self {
            k,
            postings: vec![PostingList::default(); d],
            support: vec![Vec::new(); k],
            maxw: vec![0.0; d],
            nnz: 0,
        }
    }

    /// Build the full index from a k×d centers matrix.
    pub fn from_centers(centers: &DenseMatrix) -> Self {
        let mut me = Self::new(centers.cols(), centers.rows());
        // Centers inserted in ascending id order keep every postings list
        // sorted without searching.
        for j in 0..me.k {
            for (c, &v) in centers.row(j).iter().enumerate() {
                if v != 0.0 {
                    me.postings[c].centers.push(j as u32);
                    me.postings[c].values.push(v);
                    me.support[j].push(c as u32);
                    me.maxw[c] = me.maxw[c].max(v.abs());
                    me.nnz += 1;
                }
            }
        }
        me
    }

    /// Number of centers indexed.
    #[inline]
    pub fn k(&self) -> usize {
        self.k
    }

    /// Number of dimensions indexed.
    #[inline]
    pub fn dims(&self) -> usize {
        self.postings.len()
    }

    /// Total postings (non-zero center coordinates) in the index.
    #[inline]
    pub fn nnz(&self) -> usize {
        self.nnz
    }

    /// Fraction of stored center coordinates: `nnz / (d·k)`.
    pub fn density(&self) -> f64 {
        let cells = self.postings.len() * self.k;
        if cells == 0 {
            return 0.0;
        }
        self.nnz as f64 / cells as f64
    }

    /// Rewrite the postings of center `j` from its current dense row —
    /// the incremental maintenance step for a dirty center. `O(support +
    /// d)` plus the postings-list shifts (lists hold at most k entries).
    /// The cached `maxw` table is refreshed for exactly the dimensions in
    /// the center's old ∪ new support.
    pub fn refresh_center(&mut self, j: usize, row: &[f32]) {
        debug_assert_eq!(row.len(), self.postings.len());
        let jj = j as u32;
        for &c in &self.support[j] {
            let list = &mut self.postings[c as usize];
            if let Ok(pos) = list.centers.binary_search(&jj) {
                list.centers.remove(pos);
                list.values.remove(pos);
                self.nnz -= 1;
                // Exact for removal-only dims; dims re-inserted below get
                // the cheaper max-update on top of this correct base.
                self.maxw[c as usize] = list.max_abs();
            }
        }
        // Reuse the support allocation for the new pattern.
        let mut support = std::mem::take(&mut self.support[j]);
        support.clear();
        for (c, &v) in row.iter().enumerate() {
            if v != 0.0 {
                support.push(c as u32);
                let list = &mut self.postings[c];
                let pos = list
                    .centers
                    .binary_search(&jj)
                    .expect_err("center postings were just erased");
                list.centers.insert(pos, jj);
                list.values.insert(pos, v);
                self.maxw[c] = self.maxw[c].max(v.abs());
                self.nnz += 1;
            }
        }
        self.support[j] = support;
    }

    /// Per-dimension maximum absolute center weight: `maxw[c] =
    /// max_j |centers[j][c]|` (0 where no center has the term). This is
    /// the MaxScore bound table (Turtle & Flood 1995) the serving layer
    /// and the pruned training kernel use: the contribution of dimension
    /// `c` to any point×center cosine is at most `|q_c| · maxw[c]`, so
    /// summing it over a query's unprocessed terms bounds every center's
    /// remaining similarity. Cached inside the index and maintained per
    /// dirty center — reading it is free.
    #[inline]
    pub fn max_abs_weights(&self) -> &[f32] {
        &self.maxw
    }

    /// Number of postings stored for dimension `c` (the multiply-adds a
    /// walk of that dimension costs) — what the pruned traversal's
    /// stop-rule cost model sums without touching the lists themselves.
    #[inline]
    pub fn dim_len(&self, c: usize) -> usize {
        self.postings[c].len()
    }

    /// Walk the postings of dimension `c`, folding `q · value` into
    /// `out[center]` for every center with the term, in ascending center
    /// id order (the same accumulation order [`InvertedIndex::sims_into`]
    /// uses). Returns the postings touched (= multiply-adds performed).
    #[inline]
    pub fn accumulate_dim(&self, c: usize, q: f64, out: &mut [f64]) -> u64 {
        let list = &self.postings[c];
        for (&j, &v) in list.centers.iter().zip(&list.values) {
            out[j as usize] += q * v as f64;
        }
        list.len() as u64
    }

    /// Deep invariant check for the audit layer ([`crate::audit`]): the
    /// incrementally maintained index must be **exactly** the index a
    /// from-scratch build of `centers` would produce — postings sorted by
    /// center id with in-range ids and bit-identical non-zero values,
    /// support lists matching each center's non-zero pattern, the cached
    /// `maxw` bound table bit-equal to a fresh per-dimension fold, and
    /// the `nnz` count agreeing with all of them. Run at iteration
    /// barriers under audit (via
    /// [`crate::kmeans::Centers::check_invariants`]) and callable from
    /// tests; returns the first broken invariant.
    pub fn check_invariants(&self, centers: &DenseMatrix) -> Result<(), AuditViolation> {
        let fail = |check: &'static str, detail: String| {
            Err(AuditViolation::invariant("inverted", check, detail))
        };
        if self.k != centers.rows() || self.postings.len() != centers.cols() {
            return fail(
                "shape",
                format!(
                    "index is {} centers × {} dims, centers matrix is {} × {}",
                    self.k,
                    self.postings.len(),
                    centers.rows(),
                    centers.cols()
                ),
            );
        }
        if self.support.len() != self.k {
            return fail(
                "shape",
                format!("{} support lists for {} centers", self.support.len(), self.k),
            );
        }
        if self.maxw.len() != self.postings.len() {
            return fail(
                "shape",
                format!("{} maxw entries for {} dims", self.maxw.len(), self.postings.len()),
            );
        }
        let mut counted = 0usize;
        for (c, list) in self.postings.iter().enumerate() {
            if list.centers.len() != list.values.len() {
                return fail(
                    "postings-parallel",
                    format!(
                        "dim {c}: {} center ids vs {} values",
                        list.centers.len(),
                        list.values.len()
                    ),
                );
            }
            counted += list.len();
            for w in list.centers.windows(2) {
                if w[0] >= w[1] {
                    return fail(
                        "postings-sorted",
                        format!("dim {c}: center {} then {}", w[0], w[1]),
                    );
                }
            }
            for (&jj, &v) in list.centers.iter().zip(&list.values) {
                let j = jj as usize;
                if j >= self.k {
                    return fail("postings-center-range", format!("dim {c}: center {j} >= k"));
                }
                let actual = centers.row(j)[c];
                if v.to_bits() != actual.to_bits() {
                    return fail(
                        "postings-value-coherence",
                        format!("dim {c}, center {j}: posting {v} vs center {actual}"),
                    );
                }
                if v == 0.0 {
                    return fail("postings-nonzero", format!("dim {c}, center {j}: stored zero"));
                }
            }
            let fresh = list.max_abs();
            if self.maxw[c].to_bits() != fresh.to_bits() {
                return fail(
                    "maxw-coherence",
                    format!("dim {c}: cached maxw {} vs recomputed {fresh}", self.maxw[c]),
                );
            }
        }
        if counted != self.nnz {
            return fail(
                "nnz-coherence",
                format!("nnz counter {} vs {} postings", self.nnz, counted),
            );
        }
        for (j, support) in self.support.iter().enumerate() {
            let expect: Vec<u32> = centers
                .row(j)
                .iter()
                .enumerate()
                .filter(|(_, &v)| v != 0.0)
                .map(|(c, _)| c as u32)
                .collect();
            if support != &expect {
                return fail(
                    "support-coherence",
                    format!(
                        "center {j}: support has {} dims, center row has {} non-zeros",
                        support.len(),
                        expect.len()
                    ),
                );
            }
        }
        Ok(())
    }

    /// Similarities of one sparse row to **all** centers, written into
    /// `out[0..k]`. Walks only the postings of the row's own dimensions;
    /// returns the number of multiply-adds performed (the kernel-layer
    /// cost model — strictly `≤ nnz(row)·k`, and far below it when the
    /// centers are sparse). Bit-identical to the dense-transpose kernel —
    /// see the module docs.
    pub fn sims_into(&self, row: RowView<'_>, out: &mut [f64]) -> u64 {
        debug_assert_eq!(out.len(), self.k);
        for o in out.iter_mut() {
            *o = 0.0;
        }
        let mut madds = 0u64;
        for (c, &v) in row.indices.iter().zip(row.values.iter()) {
            let list = &self.postings[*c as usize];
            madds += list.len() as u64;
            let v = v as f64;
            for (&j, &w) in list.centers.iter().zip(&list.values) {
                out[j as usize] += v * w as f64;
            }
        }
        madds
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::SparseVec;
    use crate::util::prop::forall;

    fn view(v: &SparseVec) -> RowView<'_> {
        RowView { indices: v.indices(), values: v.values() }
    }

    fn toy_centers() -> DenseMatrix {
        // 3 centers over 4 dims; center 2 is all-zero in dims {1, 3}.
        DenseMatrix::from_vec(
            3,
            4,
            vec![
                0.6, 0.0, 0.8, 0.0, //
                0.0, 1.0, 0.0, 0.0, //
                0.5, 0.0, 0.5, 0.5,
            ],
        )
    }

    #[test]
    fn builds_postings_and_counts() {
        let idx = InvertedIndex::from_centers(&toy_centers());
        assert_eq!(idx.k(), 3);
        assert_eq!(idx.dims(), 4);
        assert_eq!(idx.nnz(), 6);
        assert!((idx.density() - 6.0 / 12.0).abs() < 1e-12);
        assert_eq!(idx.max_abs_weights(), &[0.6, 1.0, 0.8, 0.5]);
        assert_eq!(idx.dim_len(0), 2);
        assert_eq!(idx.dim_len(1), 1);
    }

    #[test]
    fn sims_match_gather_dots() {
        let centers = toy_centers();
        let idx = InvertedIndex::from_centers(&centers);
        let row = SparseVec::from_pairs(4, vec![(0, 0.5), (2, -0.25), (3, 1.0)]);
        let mut out = vec![0.0f64; 3];
        let madds = idx.sims_into(view(&row), &mut out);
        // dims 0, 2 have 2 postings each, dim 3 has 1.
        assert_eq!(madds, 5);
        for (j, &s) in out.iter().enumerate() {
            let direct = view(&row).dot_dense(centers.row(j));
            assert_eq!(s.to_bits(), direct.to_bits(), "center {j}");
        }
    }

    #[test]
    fn refresh_center_rewrites_one_center_only() {
        let centers = toy_centers();
        let mut idx = InvertedIndex::from_centers(&centers);
        // Move center 1 from dim 1 to dims {0, 3}.
        let new_row = [0.6f32, 0.0, 0.0, 0.8];
        idx.refresh_center(1, &new_row);
        assert_eq!(idx.nnz(), 7);
        // maxw follows the rewrite: dim 1 loses its only posting, dim 3
        // gains the new 0.8.
        assert_eq!(idx.max_abs_weights(), &[0.6, 0.0, 0.8, 0.8]);
        let mut expect = centers.clone();
        expect.row_mut(1).copy_from_slice(&new_row);
        let row = SparseVec::from_pairs(4, vec![(0, 1.0), (1, 1.0), (3, 1.0)]);
        let mut out = vec![0.0f64; 3];
        idx.sims_into(view(&row), &mut out);
        for (j, &s) in out.iter().enumerate() {
            let direct = view(&row).dot_dense(expect.row(j));
            assert_eq!(s.to_bits(), direct.to_bits(), "center {j}");
        }
        // Refreshing with the same row is idempotent.
        idx.refresh_center(1, &new_row);
        assert_eq!(idx.nnz(), 7);
        assert!(idx.check_invariants(&expect).is_ok());
    }

    #[test]
    fn prop_incremental_refresh_equals_rebuild() {
        forall(80, 0x1F5, |g| {
            let d = g.usize_in(1, 40);
            let k = g.usize_in(1, 10);
            let mut centers = DenseMatrix::zeros(k, d);
            let mut fill = |m: &mut DenseMatrix, g: &mut crate::util::prop::Gen| {
                for j in 0..k {
                    let nnz = g.usize_in(0, d + 1);
                    let pat = g.sparse_pattern(d, nnz);
                    let row = m.row_mut(j);
                    row.fill(0.0);
                    for c in pat {
                        row[c] = g.f64_in(-1.0, 1.0) as f32;
                    }
                }
            };
            fill(&mut centers, g);
            let mut idx = InvertedIndex::from_centers(&centers);
            // Mutate a few random centers and refresh them incrementally.
            for _ in 0..g.usize_in(1, 5) {
                let j = g.usize_in(0, k);
                let nnz = g.usize_in(0, d + 1);
                let pat = g.sparse_pattern(d, nnz);
                let row = centers.row_mut(j);
                row.fill(0.0);
                for c in pat {
                    row[c] = g.f64_in(-1.0, 1.0) as f32;
                }
                idx.refresh_center(j, centers.row(j));
            }
            // The incrementally maintained index must equal a from-scratch
            // rebuild: same nnz, bit-identical similarities, and a
            // bit-identical cached maxw bound table.
            let rebuilt = InvertedIndex::from_centers(&centers);
            assert_eq!(idx.nnz(), rebuilt.nnz());
            for (c, (x, y)) in idx
                .max_abs_weights()
                .iter()
                .zip(rebuilt.max_abs_weights())
                .enumerate()
            {
                assert_eq!(x.to_bits(), y.to_bits(), "maxw[{c}]");
            }
            assert!(idx.check_invariants(&centers).is_ok());
            let nnz = g.usize_in(0, d + 1);
            let pat = g.sparse_pattern(d, nnz);
            let row = SparseVec::new(
                d,
                pat.iter().map(|&c| c as u32).collect(),
                pat.iter().map(|_| g.f64_in(-1.0, 1.0) as f32).collect(),
            );
            let mut a = vec![0.0f64; k];
            let mut b = vec![0.0f64; k];
            let ma = idx.sims_into(view(&row), &mut a);
            let mb = rebuilt.sims_into(view(&row), &mut b);
            assert_eq!(ma, mb);
            for (x, y) in a.iter().zip(&b) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
        });
    }

    #[test]
    fn check_invariants_accepts_valid_and_names_broken_coherence() {
        let centers = toy_centers();
        assert!(InvertedIndex::from_centers(&centers).check_invariants(&centers).is_ok());

        // A posting diverging from the centers matrix it claims to mirror.
        let mut idx = InvertedIndex::from_centers(&centers);
        idx.postings[0].values[0] += 1.0;
        assert_eq!(
            idx.check_invariants(&centers).unwrap_err().check,
            "postings-value-coherence"
        );

        // Checked against a differently shaped center bank.
        let idx = InvertedIndex::from_centers(&centers);
        let other = DenseMatrix::from_vec(2, 4, vec![0.0; 8]);
        assert_eq!(idx.check_invariants(&other).unwrap_err().check, "shape");

        // Stale total-postings counter.
        let mut idx = InvertedIndex::from_centers(&centers);
        idx.nnz += 1;
        assert_eq!(idx.check_invariants(&centers).unwrap_err().check, "nnz-coherence");

        // Stale cached MaxScore bound table.
        let mut idx = InvertedIndex::from_centers(&centers);
        idx.maxw[2] = 0.1;
        assert_eq!(idx.check_invariants(&centers).unwrap_err().check, "maxw-coherence");
    }
}
