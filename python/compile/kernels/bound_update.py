"""L1 Pallas kernel: elementwise bound maintenance (Eq. 6 + Eq. 9).

The per-iteration bound update touches every point (`O(N)` for Hamerly,
`O(N·k)` for Elkan) and is purely elementwise — a bandwidth-bound VPU
kernel on TPU. Tiled 1-D with a block of 1024 lanes (8×128 VPU registers).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


BLOCK = 1024


def _bound_kernel(l_ref, u_ref, pa_ref, pc_ref, lo_ref, uo_ref):
    l = jnp.clip(l_ref[...], -1.0, 1.0)
    u = jnp.clip(u_ref[...], -1.0, 1.0)
    pa = jnp.clip(pa_ref[...], -1.0, 1.0)
    pc = jnp.maximum(pc_ref[...], 0.0)
    sin_l = jnp.sqrt(jnp.maximum(1.0 - l * l, 0.0))
    sin_p = jnp.sqrt(jnp.maximum(1.0 - pa * pa, 0.0))
    l_new = l * pa - sin_l * sin_p  # Eq. 6
    l_new = jnp.where(pa <= -l, -1.0, l_new)  # saturation guard
    u_new = u + jnp.sqrt(jnp.maximum(1.0 - u * u, 0.0) * pc)  # Eq. 9
    lo_ref[...] = jnp.clip(l_new, -1.0, 1.0)
    uo_ref[...] = jnp.clip(u_new, -1.0, 1.0)


def _pick_block(n, want):
    b = min(n, want)
    while n % b != 0:
        b -= 1
    return b


@jax.jit
def bound_update(l, u, p_a, p_min_sq_comp):
    """Updated ``(l, u)`` per Eq. 6 / Eq. 9 with saturation guards.

    All four inputs are f32 vectors of the same length (``p_a`` and
    ``p_min_sq_comp`` are pre-gathered per point by the caller).
    """
    (n,) = l.shape
    bn = _pick_block(n, BLOCK)
    grid = (n // bn,)
    spec = pl.BlockSpec((bn,), lambda i: (i,))
    return pl.pallas_call(
        _bound_kernel,
        grid=grid,
        in_specs=[spec, spec, spec, spec],
        out_specs=(spec, spec),
        out_shape=(
            jax.ShapeDtypeStruct((n,), jnp.float32),
            jax.ShapeDtypeStruct((n,), jnp.float32),
        ),
        interpret=True,
    )(l, u, p_a, p_min_sq_comp)
