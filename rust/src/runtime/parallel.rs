//! Sharded parallel execution for the assignment hot loop.
//!
//! The k-means assignment phase is embarrassingly parallel *within one
//! iteration*: every point's decision depends only on the (frozen) centers
//! and the point's own bound state. This module supplies the two pieces the
//! algorithm layer builds on:
//!
//! * [`Plan`] — a row-shard splitter. The shard grid is a pure function of
//!   the **row count** (never of the thread count), which is the first half
//!   of the crate's shard-determinism contract (see [`crate::kmeans`]):
//!   any floating-point reduction tree keyed on shard boundaries is
//!   identical for every `threads` setting.
//! * [`Pool`] — a worker pool (rayon) that maps a closure over per-shard
//!   work items and returns the outputs **in shard order**. With one
//!   worker (`threads = 1`, the default) no thread pool is created at all
//!   and the closure runs inline on the caller's thread — the exact serial
//!   path.
//!
//! Shard-local mutable state (assignments, bounds) is carved out of the
//! backing vectors with [`split_mut`], so shards never contend and no
//! locks are needed; cross-shard effects (center updates, counters) are
//! represented as per-shard values merged deterministically at the barrier
//! by the caller.

use std::ops::Range;

/// Target rows per shard. Small enough that test-sized corpora (a few
/// hundred rows) still split into several shards — exercising the merge
/// path — while keeping per-shard scratch allocation negligible against
/// the `O(rows × k)` similarity work inside a shard.
pub const SHARD_ROWS: usize = 256;

/// Upper bound on the number of shards, so very large corpora get
/// proportionally larger shards instead of unbounded task counts.
pub const MAX_SHARDS: usize = 64;

/// A contiguous row-shard grid over `0..rows`.
///
/// Ranges are contiguous, ascending, non-overlapping, and cover the row
/// space exactly. The grid depends only on `rows` — see the module docs
/// for why that matters.
#[derive(Debug, Clone)]
pub struct Plan {
    ranges: Vec<Range<usize>>,
    rows: usize,
}

impl Plan {
    /// The canonical grid for `rows` data rows:
    /// `ceil(rows / SHARD_ROWS)` shards, capped at [`MAX_SHARDS`].
    pub fn for_rows(rows: usize) -> Plan {
        let parts = rows.div_ceil(SHARD_ROWS).clamp(1, MAX_SHARDS);
        Plan::with_parts(rows, parts)
    }

    /// An explicit grid: `parts` near-equal contiguous shards over
    /// `0..rows` (the first `rows % parts` shards hold one extra row).
    /// Empty when `rows == 0`.
    pub fn with_parts(rows: usize, parts: usize) -> Plan {
        let mut ranges = Vec::new();
        if rows > 0 {
            let parts = parts.clamp(1, rows);
            let base = rows / parts;
            let extra = rows % parts;
            let mut start = 0;
            for s in 0..parts {
                let len = base + usize::from(s < extra);
                ranges.push(start..start + len);
                start += len;
            }
            debug_assert_eq!(start, rows);
        }
        Plan { ranges, rows }
    }

    /// The shard ranges, in ascending row order.
    pub fn ranges(&self) -> &[Range<usize>] {
        &self.ranges
    }

    /// Number of shards.
    pub fn len(&self) -> usize {
        self.ranges.len()
    }

    /// True when the plan covers zero rows.
    pub fn is_empty(&self) -> bool {
        self.ranges.is_empty()
    }

    /// Total rows covered.
    pub fn rows(&self) -> usize {
        self.rows
    }
}

/// Split a flat per-row buffer (`width` entries per row) into one
/// non-overlapping mutable slice per shard of `plan`, in shard order.
///
/// This is how shard workers get lock-free mutable access to their rows of
/// the assignment vector and the bound arrays.
pub fn split_mut<'a, T>(plan: &Plan, width: usize, buf: &'a mut [T]) -> Vec<&'a mut [T]> {
    assert_eq!(
        buf.len(),
        plan.rows() * width,
        "buffer length does not match plan rows × width"
    );
    let mut rest = buf;
    let mut out = Vec::with_capacity(plan.len());
    for r in plan.ranges() {
        let (head, tail) = std::mem::take(&mut rest).split_at_mut(r.len() * width);
        out.push(head);
        rest = tail;
    }
    out
}

/// A worker pool executing per-shard closures.
///
/// `threads == 1` (the default in [`crate::kmeans::KMeansConfig`]) never
/// builds a thread pool: work runs inline, in shard order, on the calling
/// thread. `threads == 0` resolves to all available cores.
pub struct Pool {
    threads: usize,
    pool: Option<rayon::ThreadPool>,
}

impl std::fmt::Debug for Pool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Pool")
            .field("threads", &self.threads)
            .field("serial", &self.pool.is_none())
            .finish()
    }
}

impl Pool {
    /// Build a pool for `threads` workers (`0` = all available cores).
    ///
    /// If the underlying thread pool cannot be created (resource limits),
    /// the pool silently degrades to serial execution — results are
    /// identical either way by the determinism contract.
    pub fn new(threads: usize) -> Pool {
        let threads = if threads == 0 {
            std::thread::available_parallelism()
                .map(|c| c.get())
                .unwrap_or(1)
        } else {
            threads
        };
        let pool = if threads > 1 {
            rayon::ThreadPoolBuilder::new()
                .num_threads(threads)
                .build()
                .ok()
        } else {
            None
        };
        Pool { threads, pool }
    }

    /// Resolved worker count (after expanding `0` to the core count).
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// True when work will run inline on the caller's thread.
    pub fn is_serial(&self) -> bool {
        self.pool.is_none()
    }

    /// Run `f(shard_index, work)` over every work item and return the
    /// outputs in input (shard) order. Serial pools, and work lists of at
    /// most one item, run inline.
    pub fn run<W, O, F>(&self, works: Vec<W>, f: F) -> Vec<O>
    where
        W: Send,
        O: Send,
        F: Fn(usize, W) -> O + Sync + Send,
    {
        match &self.pool {
            Some(pool) if works.len() > 1 => {
                use rayon::prelude::*;
                pool.install(|| {
                    works
                        .into_par_iter()
                        .enumerate()
                        .map(|(s, w)| f(s, w))
                        .collect()
                })
            }
            _ => works
                .into_iter()
                .enumerate()
                .map(|(s, w)| f(s, w))
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::forall;

    #[test]
    fn plan_partitions_rows_exactly() {
        forall(300, 0x9A11, |g| {
            let n = g.usize_in(0, 40_000);
            let plan = Plan::for_rows(n);
            assert_eq!(plan.rows(), n);
            assert!(plan.len() <= MAX_SHARDS);
            let mut next = 0usize;
            for r in plan.ranges() {
                assert_eq!(r.start, next, "shards must be contiguous ascending");
                assert!(r.end > r.start, "no empty shards");
                next = r.end;
            }
            assert_eq!(next, n, "shards must cover all rows");
            if n > 0 {
                assert!(!plan.is_empty());
            }
        });
    }

    #[test]
    fn plan_depends_on_rows_only() {
        // Same n → same grid, trivially; also: with_parts sizes differ by
        // at most one row, largest first.
        for n in [1usize, 7, 255, 256, 257, 1000, 64 * 256 + 1, 1 << 20] {
            let a = Plan::for_rows(n);
            let b = Plan::for_rows(n);
            assert_eq!(a.ranges(), b.ranges());
            let lens: Vec<usize> = a.ranges().iter().map(|r| r.len()).collect();
            let (mn, mx) = (
                *lens.iter().min().unwrap(),
                *lens.iter().max().unwrap(),
            );
            assert!(mx - mn <= 1, "near-equal shards for n={n}: {lens:?}");
        }
        assert!(Plan::for_rows(0).is_empty());
    }

    #[test]
    fn split_mut_carves_disjoint_row_slices() {
        forall(200, 0x9A12, |g| {
            let n = g.usize_in(1, 2000);
            let width = g.usize_in(1, 5);
            let plan = Plan::for_rows(n);
            let mut buf = vec![0u32; n * width];
            let shards = split_mut(&plan, width, &mut buf);
            assert_eq!(shards.len(), plan.len());
            for (slice, r) in shards.into_iter().zip(plan.ranges()) {
                assert_eq!(slice.len(), r.len() * width);
                // Write a marker through each shard...
                for v in slice.iter_mut() {
                    *v += 1;
                }
            }
            // ...and confirm full, single coverage of the backing buffer.
            assert!(buf.iter().all(|&v| v == 1));
        });
    }

    #[test]
    fn pool_preserves_shard_order_and_matches_serial() {
        let works: Vec<usize> = (0..23).collect();
        let serial = Pool::new(1).run(works.clone(), |s, w| (s, w * w));
        for threads in [2usize, 4, 0] {
            let par = Pool::new(threads).run(works.clone(), |s, w| (s, w * w));
            assert_eq!(par, serial, "threads={threads}");
        }
        for (s, (idx, _)) in serial.iter().enumerate() {
            assert_eq!(s, *idx);
        }
    }

    #[test]
    fn pool_zero_resolves_to_cores() {
        let p = Pool::new(0);
        assert!(p.threads() >= 1);
        let q = Pool::new(1);
        assert!(q.is_serial());
        assert_eq!(q.threads(), 1);
    }
}
