//! Query-engine integration tests: the MaxScore-pruned traversal must be
//! bit-identical to exhaustive gather on random sparse problems, across
//! top-p widths and thread counts, and a converged model's p = 1 answers
//! must reproduce its training assignments.

// Bench and test targets favour readable literal casts and exact
// (bit-level) float assertions; the workspace clippy warnings on
// those patterns are aimed at library code.
#![allow(clippy::cast_possible_truncation, clippy::float_cmp)]

use sphkm::kmeans::{KernelChoice, Variant};
use sphkm::model::{Model, TrainingMeta};
use sphkm::serve::{QueryEngine, ServeConfig, ServeMode};
use sphkm::sparse::{CsrMatrix, DenseMatrix, SparseVec};
use sphkm::util::prop::forall;
use sphkm::SphericalKMeans;

fn meta() -> TrainingMeta {
    TrainingMeta {
        variant: "Standard".into(),
        kernel: "gather".into(),
        iterations: 0,
        objective: 0.0,
        seed: 0,
    }
}

#[test]
fn prop_pruned_top_p_is_bit_identical_to_exhaustive() {
    forall(60, 0x5E4E, |g| {
        let d = g.usize_in(1, 100);
        let k = g.usize_in(1, 16);
        let mut centers = DenseMatrix::zeros(k, d);
        for j in 0..k {
            let nnz = g.usize_in(0, d + 1);
            for c in g.sparse_pattern(d, nnz) {
                centers.row_mut(j)[c] = g.f64_in(-1.0, 1.0) as f32;
            }
        }
        let engine = QueryEngine::new(
            Model::new(centers, meta()),
            &ServeConfig { mode: ServeMode::Pruned, threads: 1 },
        );
        let rows: Vec<SparseVec> = (0..g.usize_in(1, 20))
            .map(|_| {
                let nnz = g.usize_in(0, d + 1);
                let pat = g.sparse_pattern(d, nnz);
                SparseVec::new(
                    d,
                    pat.iter().map(|&c| c as u32).collect(),
                    pat.iter().map(|_| g.f64_in(-1.0, 1.0) as f32).collect(),
                )
            })
            .collect();
        let data = CsrMatrix::from_rows(d, &rows);
        for p in [1usize, 2, k, k + 3] {
            let (ex, ex_stats) = engine.top_p_batch_exhaustive(&data, p);
            let (pr, pr_stats) = engine.top_p_batch_pruned(&data, p);
            assert_eq!(ex.len(), pr.len());
            for (i, (a, b)) in ex.iter().zip(&pr).enumerate() {
                assert_eq!(a.len(), b.len(), "row {i} p={p}");
                assert_eq!(a.len(), p.min(k));
                for (x, y) in a.iter().zip(b) {
                    assert_eq!(x.0, y.0, "row {i} p={p}: center order");
                    assert_eq!(x.1.to_bits(), y.1.to_bits(), "row {i} p={p}: sims");
                }
            }
            // Correctness never depends on the cost: on adversarial dense
            // centers the bound pass can even cost extra (which is why
            // Auto serves dense models exhaustively), so only the query
            // accounting is asserted here; the strict madds win on sparse
            // text models is asserted by `bench_serve`.
            assert_eq!(pr_stats.queries, ex_stats.queries);
        }
    });
}

#[test]
fn batch_queries_are_thread_count_invariant() {
    let ds = sphkm::data::synth::SynthConfig::small_demo().generate(11);
    let fitted = SphericalKMeans::new(8).seed(3).max_iter(25).fit(&ds.matrix).unwrap();
    let model = fitted.to_model();
    let serial = QueryEngine::new(
        model.clone(),
        &ServeConfig { mode: ServeMode::Pruned, threads: 1 },
    );
    let (base, base_stats) = serial.top_p_batch(&ds.matrix, 4);
    for threads in [2usize, 4, 0] {
        let engine = QueryEngine::new(
            model.clone(),
            &ServeConfig { mode: ServeMode::Pruned, threads },
        );
        let (out, stats) = engine.top_p_batch(&ds.matrix, 4);
        assert_eq!(stats, base_stats, "threads={threads}: stats");
        assert_eq!(out.len(), base.len());
        for (i, (a, b)) in base.iter().zip(&out).enumerate() {
            for (x, y) in a.iter().zip(b) {
                assert_eq!(x.0, y.0, "threads={threads} row {i}");
                assert_eq!(x.1.to_bits(), y.1.to_bits(), "threads={threads} row {i}");
            }
        }
    }
}

#[test]
fn converged_model_reproduces_training_assignments() {
    // A converged run assigns every point to its most-similar center; the
    // gather kernel computes training similarities with the very dot the
    // serving engine uses, so p = 1 answers must reproduce the training
    // assignments exactly — through a disk round trip.
    let ds = sphkm::data::synth::SynthConfig::small_demo().generate(21);
    let fitted = SphericalKMeans::new(6)
        .variant(Variant::Standard)
        .kernel(KernelChoice::Gather)
        .seed(9)
        .max_iter(200)
        .fit(&ds.matrix)
        .unwrap();
    assert!(fitted.converged(), "demo corpus must converge");
    let path =
        std::env::temp_dir().join(format!("sphkm-serve-e2e-{}.spkm", std::process::id()));
    fitted.save(&path).unwrap();
    let model = Model::load(&path).unwrap();
    std::fs::remove_file(&path).ok();
    for mode in [ServeMode::Pruned, ServeMode::Exhaustive, ServeMode::Auto] {
        let engine = QueryEngine::new(model.clone(), &ServeConfig { mode, threads: 0 });
        let (labels, stats) = engine.assign_batch(&ds.matrix);
        assert_eq!(labels, fitted.assignments(), "mode={}", mode.name());
        assert_eq!(stats.queries, ds.matrix.rows() as u64);
    }
    // The FittedModel's own serving bridge answers identically.
    let engine = fitted.query_engine(ServeMode::Auto);
    let (labels, _) = engine.assign_batch(&ds.matrix);
    assert_eq!(labels, fitted.assignments());
}

#[test]
fn auto_mode_resolves_by_center_density() {
    // Sparse centers over a large vocabulary → pruned; dense centers over
    // a tiny one → exhaustive (mirrors the kernel layer's Auto heuristic).
    let mut sparse = DenseMatrix::zeros(8, 10_000);
    for j in 0..8 {
        sparse.row_mut(j)[j * 7] = 1.0;
    }
    let engine = QueryEngine::new(
        Model::new(sparse, meta()),
        &ServeConfig { mode: ServeMode::Auto, threads: 1 },
    );
    assert_eq!(engine.mode(), "pruned");
    assert!(engine.index_density() < 0.01);
    let dense = DenseMatrix::from_vec(2, 2, vec![0.6, 0.8, 0.8, 0.6]);
    let engine = QueryEngine::new(
        Model::new(dense, meta()),
        &ServeConfig { mode: ServeMode::Auto, threads: 1 },
    );
    assert_eq!(engine.mode(), "exhaustive");
}
