//! Plain-text ingestion: tokenizer, vocabulary building with stopword and
//! document-frequency filtering — the Simpsons-wiki-style pipeline of §6
//! ("tokenized and lemmatized, stop words were removed as well as
//! infrequent tokens"). Lemmatization is approximated by a light suffix
//! stemmer (no NLP models are available offline).

use super::tfidf::TfIdf;
use super::Dataset;
use crate::sparse::{CsrMatrix, SparseVec};
use std::collections::HashMap;

/// A small English stopword list (the usual suspects).
pub const STOPWORDS: &[&str] = &[
    "a", "about", "above", "after", "again", "all", "also", "an", "and", "any",
    "are", "as", "at", "be", "because", "been", "before", "being", "below",
    "between", "both", "but", "by", "can", "could", "did", "do", "does",
    "doing", "down", "during", "each", "few", "for", "from", "further", "had",
    "has", "have", "having", "he", "her", "here", "hers", "him", "his", "how",
    "i", "if", "in", "into", "is", "it", "its", "just", "me", "more", "most",
    "my", "no", "nor", "not", "now", "of", "off", "on", "once", "only", "or",
    "other", "our", "ours", "out", "over", "own", "s", "same", "she", "so",
    "some", "such", "t", "than", "that", "the", "their", "theirs", "them",
    "then", "there", "these", "they", "this", "those", "through", "to", "too",
    "under", "until", "up", "very", "was", "we", "were", "what", "when",
    "where", "which", "while", "who", "whom", "why", "will", "with", "you",
    "your", "yours",
];

/// Tokenizer + vocabulary filter configuration.
#[derive(Debug, Clone)]
pub struct TextPipeline {
    /// Lowercase and keep alphabetic tokens of at least this length.
    pub min_token_len: usize,
    /// Drop tokens appearing in fewer than `min_df` documents.
    pub min_df: u32,
    /// Drop tokens appearing in more than this fraction of documents.
    pub max_df_frac: f64,
    /// Remove [`STOPWORDS`].
    pub remove_stopwords: bool,
    /// Apply the light suffix stemmer.
    pub stem: bool,
    /// TF-IDF weighting for the final matrix.
    pub tfidf: TfIdf,
}

impl Default for TextPipeline {
    fn default() -> Self {
        Self {
            min_token_len: 2,
            min_df: 2,
            max_df_frac: 0.5,
            remove_stopwords: true,
            stem: true,
            tfidf: TfIdf::default(),
        }
    }
}

/// Lowercase alphabetic tokenization.
pub fn tokenize(text: &str) -> impl Iterator<Item = String> + '_ {
    text.split(|c: char| !c.is_alphabetic())
        .filter(|t| !t.is_empty())
        .map(|t| t.to_lowercase())
}

/// A light suffix stemmer (Porter-step-1-ish): plural/participle suffixes.
pub fn stem(token: &str) -> String {
    let t = token;
    for (suffix, repl) in [
        ("sses", "ss"),
        ("ies", "i"),
        ("ing", ""),
        ("edly", ""),
        ("ed", ""),
        ("ly", ""),
        ("s", ""),
    ] {
        if t.len() > suffix.len() + 2 && t.ends_with(suffix) {
            return format!("{}{}", &t[..t.len() - suffix.len()], repl);
        }
    }
    t.to_string()
}

impl TextPipeline {
    /// Turn a collection of documents into a TF-IDF matrix + vocabulary.
    /// Returns `(dataset, vocabulary)` where `vocabulary[j]` is the token of
    /// column `j`.
    pub fn fit(&self, docs: &[String], name: &str) -> (Dataset, Vec<String>) {
        let stop: std::collections::HashSet<&str> = if self.remove_stopwords {
            STOPWORDS.iter().copied().collect()
        } else {
            Default::default()
        };
        // Pass 1: token streams per doc (post stop/stem filtering).
        let mut doc_tokens: Vec<Vec<String>> = Vec::with_capacity(docs.len());
        for d in docs {
            let mut toks = Vec::new();
            for t in tokenize(d) {
                if t.len() < self.min_token_len || stop.contains(t.as_str()) {
                    continue;
                }
                toks.push(if self.stem { stem(&t) } else { t });
            }
            doc_tokens.push(toks);
        }
        // Pass 2: document frequencies.
        let mut df: HashMap<&str, u32> = HashMap::new();
        for toks in &doc_tokens {
            let uniq: std::collections::HashSet<&str> =
                toks.iter().map(|s| s.as_str()).collect();
            for t in uniq {
                *df.entry(t).or_insert(0) += 1;
            }
        }
        let max_df = (docs.len() as f64 * self.max_df_frac).ceil() as u32;
        let mut vocab: Vec<String> = df
            .iter()
            .filter(|(_, &d)| d >= self.min_df && d <= max_df)
            .map(|(t, _)| t.to_string())
            .collect();
        vocab.sort(); // deterministic column order
        let index: HashMap<&str, u32> = vocab
            .iter()
            .enumerate()
            .map(|(i, t)| (t.as_str(), i as u32))
            .collect();
        // Pass 3: counts.
        let mut rows = Vec::with_capacity(docs.len());
        for toks in &doc_tokens {
            let mut pairs: Vec<(u32, f32)> = Vec::new();
            for t in toks {
                if let Some(&j) = index.get(t.as_str()) {
                    pairs.push((j, 1.0));
                }
            }
            rows.push(SparseVec::from_pairs(vocab.len().max(1), pairs));
        }
        let counts = CsrMatrix::from_rows(vocab.len().max(1), &rows);
        let matrix = self.tfidf.apply(&counts);
        (
            Dataset { name: name.into(), matrix, labels: None },
            vocab,
        )
    }
}

/// A tiny built-in demo corpus (three obvious themes) so the
/// `text_clustering` example runs without external files.
pub fn demo_corpus() -> Vec<String> {
    let space = [
        "the rocket launched the satellite into orbit and the spacecraft circled the moon",
        "astronauts aboard the spacecraft observed the satellite from lunar orbit",
        "the rocket carried the astronauts into orbit around the moon",
        "mission control confirmed the spacecraft and its satellite entered orbit",
        "the satellite orbited the moon while astronauts monitored the rocket stage",
        "a rocket launch placed the orbiting satellite above the lunar spacecraft",
    ];
    let cooking = [
        "simmer the garlic and onions in olive oil and cook the sauce slowly",
        "the recipe says to cook the garlic in olive oil before adding the sauce",
        "cook the pasta and toss it with garlic olive oil and tomato sauce",
        "this recipe simmers onions and garlic in oil for a rich sauce",
        "add olive oil and garlic to the pan and cook until the sauce thickens",
        "a simple recipe of oil garlic and fresh tomato sauce over pasta",
    ];
    let football = [
        "the striker scored a goal and the team won the match before the fans",
        "the goalkeeper saved a penalty but the team lost the match by one goal",
        "the team passed the ball well and scored two goals in the match",
        "fans cheered as the team scored the winning goal of the match",
        "a late goal from the striker gave the team victory in the final match",
        "the match ended with the team celebrating the decisive goal with fans",
    ];
    space
        .iter()
        .chain(cooking.iter())
        .chain(football.iter())
        .map(|s| s.to_string())
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tokenizer_splits_and_lowercases() {
        let toks: Vec<String> = tokenize("Hello, World! 123 foo_bar").collect();
        assert_eq!(toks, vec!["hello", "world", "foo", "bar"]);
    }

    #[test]
    fn stemmer_basics() {
        assert_eq!(stem("running"), "runn");
        assert_eq!(stem("cakes"), "cake");
        assert_eq!(stem("cities"), "citi");
        // Too-short tokens are left alone.
        assert_eq!(stem("is"), "is");
        assert_eq!(stem("bus"), "bus");
    }

    #[test]
    fn pipeline_filters_stopwords_and_rare_tokens() {
        let docs: Vec<String> = vec![
            "the cat sat on the mat".into(),
            "the cat ate the fish".into(),
            "a dog chased the cat".into(),
        ];
        let p = TextPipeline {
            min_df: 2,
            max_df_frac: 1.0,
            stem: false,
            ..Default::default()
        };
        let (ds, vocab) = p.fit(&docs, "t");
        assert!(!vocab.iter().any(|t| t == "the"), "stopword kept");
        assert!(vocab.iter().any(|t| t == "cat"));
        // 'mat', 'fish', 'dog' each appear once: filtered by min_df=2.
        assert!(!vocab.iter().any(|t| t == "mat"));
        assert_eq!(ds.matrix.rows(), 3);
        assert_eq!(ds.matrix.cols(), vocab.len());
    }

    #[test]
    fn demo_corpus_clusters_by_theme() {
        let docs = demo_corpus();
        let p = TextPipeline { min_df: 1, max_df_frac: 0.9, ..Default::default() };
        let (ds, _) = p.fit(&docs, "demo");
        // Average within-theme similarity must exceed cross-theme.
        let theme = |i: usize| i / 6;
        let mut same = (0.0, 0);
        let mut cross = (0.0, 0);
        for i in 0..docs.len() {
            for j in (i + 1)..docs.len() {
                let s = ds.matrix.row(i).dot(&ds.matrix.row(j));
                if theme(i) == theme(j) {
                    same = (same.0 + s, same.1 + 1);
                } else {
                    cross = (cross.0 + s, cross.1 + 1);
                }
            }
        }
        assert!(same.0 / same.1 as f64 > cross.0 / cross.1 as f64);
    }
}
