"""L2 JAX model: the dense assignment step of spherical k-means.

Composes the L1 Pallas similarity kernel with the top-2 reduction every
bound-based variant needs (best center, best similarity, second-best
similarity), plus the center–center bound graph. `aot.py` lowers these
functions once to HLO text; the Rust runtime executes them via PJRT with
Python long gone.
"""

import jax
import jax.numpy as jnp

from .kernels import similarity as simk


def assign_step(x, c):
    """Dense tile assignment: ``(best i32[B], best_sim f32[B], second f32[B])``.

    ``x[B,D]`` is a (densified) tile of unit rows, ``c[K,D]`` the current
    unit centers. The similarity matrix comes from the Pallas kernel; the
    top-2 reduction lowers to the same HLO module and fuses with it.
    """
    sims = simk.similarity(x, c)
    k = sims.shape[1]
    if k == 1:
        b = sims.shape[0]
        return (
            jnp.zeros(b, dtype=jnp.int32),
            sims[:, 0],
            jnp.full(b, -1.0, dtype=sims.dtype),
        )
    # Top-2 via argmax + mask + max rather than jax.lax.top_k: top_k lowers
    # to the modern `topk(..., largest=true)` HLO op, which the xla crate's
    # XLA 0.5.1 text parser rejects; these classic ops round-trip fine.
    best_idx = jnp.argmax(sims, axis=1).astype(jnp.int32)
    best = jnp.max(sims, axis=1)
    is_best = jnp.arange(k, dtype=jnp.int32)[None, :] == best_idx[:, None]
    masked = jnp.where(is_best, -jnp.inf, sims)
    second = jnp.max(masked, axis=1)
    return best_idx, best, second


def cc_step(c):
    """Center–center half-angle bounds ``cc[K,K]`` and ``s[K]`` (§5.2),
    using the Pallas kernel for the K×K similarity matrix."""
    sims = jnp.clip(simk.similarity(c, c), -1.0, 1.0)
    cc = jnp.sqrt((sims + 1.0) * 0.5)
    k = cc.shape[0]
    masked = jnp.where(jnp.eye(k, dtype=bool), -jnp.inf, cc)
    return cc, jnp.max(masked, axis=1)
