//! The PJRT assignment engine: loads an AOT-lowered HLO module (produced by
//! `python/compile/aot.py` from the JAX model calling the Pallas similarity
//! kernel) and executes it on the PJRT CPU client.
//!
//! The module computes, for a dense tile of points `X[B,D]` and centers
//! `C[K,D]`: the best cluster index, the best similarity, and the
//! second-best similarity per point — exactly the quantities every bound
//! -based variant needs to (re)initialize `l(i)`/`u(i)`.
//!
//! Interchange is HLO *text*, not serialized protos: jax ≥ 0.5 emits
//! 64-bit instruction ids that xla_extension 0.5.1 rejects, while the text
//! parser reassigns ids (see /opt/xla-example/README.md).
//!
//! Everything that touches the `xla` crate is gated behind the `pjrt`
//! feature (off by default — the PJRT C library does not exist on clean
//! machines). [`Manifest`] and [`artifacts_available`] are dependency-free
//! and always compiled.

#[cfg(feature = "pjrt")]
use crate::sparse::CsrMatrix;
use std::path::{Path, PathBuf};

/// Errors from the PJRT engine.
#[derive(Debug, thiserror::Error)]
pub enum EngineError {
    /// Artifact directory or file missing.
    #[error("artifact not found: {0} (run `make artifacts`)")]
    MissingArtifact(PathBuf),
    /// Underlying XLA error.
    #[error("xla: {0}")]
    Xla(String),
    /// Shape mismatch between engine and data.
    #[error("shape mismatch: {0}")]
    Shape(String),
}

#[cfg(feature = "pjrt")]
impl From<xla::Error> for EngineError {
    fn from(e: xla::Error) -> Self {
        EngineError::Xla(e.to_string())
    }
}

/// Manifest describing the shapes an artifact was lowered for.
/// Mirrors `python/compile/aot.py`'s `--batch/--k/--dim` arguments, parsed
/// from the artifact filename `assign_b{B}_k{K}_d{D}.hlo.txt`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Manifest {
    /// Tile size (rows of X per execution).
    pub batch: usize,
    /// Number of centers.
    pub k: usize,
    /// Dimensionality.
    pub dim: usize,
}

/// Parse a field of the artifact filename: digits only, no signs, no
/// whitespace, no leading zeros, no `_`-separated trailing segments
/// (`usize::from_str` alone would accept a leading `+` or `08`, and a name
/// like `assign_b8_k10_d128_k2` must not round-trip to a different
/// filename than it was parsed from).
fn digits(s: &str) -> Option<usize> {
    if s.is_empty() || !s.bytes().all(|b| b.is_ascii_digit()) {
        return None;
    }
    if s.len() > 1 && s.starts_with('0') {
        return None;
    }
    s.parse().ok()
}

impl Manifest {
    /// Artifact filename for this shape.
    pub fn filename(&self) -> String {
        format!("assign_b{}_k{}_d{}.hlo.txt", self.batch, self.k, self.dim)
    }

    /// Parse a manifest back out of a filename. Strict inverse of
    /// [`Manifest::filename`]: every parsed name re-renders to itself, and
    /// names with extra or malformed segments are rejected rather than
    /// silently mis-parsed.
    pub fn parse(name: &str) -> Option<Manifest> {
        let rest = name.strip_prefix("assign_b")?.strip_suffix(".hlo.txt")?;
        let (b, rest) = rest.split_once("_k")?;
        let (k, d) = rest.split_once("_d")?;
        Some(Manifest {
            batch: digits(b)?,
            k: digits(k)?,
            dim: digits(d)?,
        })
    }
}

/// Whether any assignment artifacts exist under `dir` (used by tests and
/// examples to skip gracefully before `make artifacts`).
pub fn artifacts_available(dir: &Path) -> bool {
    list_artifacts(dir).map(|v| !v.is_empty()).unwrap_or(false)
}

fn list_artifacts(dir: &Path) -> std::io::Result<Vec<(Manifest, PathBuf)>> {
    let mut out = Vec::new();
    for entry in std::fs::read_dir(dir)? {
        let entry = entry?;
        let name = entry.file_name();
        let name = name.to_string_lossy();
        if let Some(m) = Manifest::parse(&name) {
            out.push((m, entry.path()));
        }
    }
    Ok(out)
}

/// A compiled PJRT executable for one `(batch, k, dim)` shape.
#[cfg(feature = "pjrt")]
pub struct AssignEngine {
    client: xla::PjRtClient,
    exe: xla::PjRtLoadedExecutable,
    manifest: Manifest,
    /// Reused staging buffer for densifying sparse tiles.
    stage: Vec<f32>,
}

#[cfg(feature = "pjrt")]
impl std::fmt::Debug for AssignEngine {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("AssignEngine")
            .field("manifest", &self.manifest)
            .finish()
    }
}

/// Result of one engine execution over a tile.
#[derive(Debug, Clone)]
pub struct AssignTile {
    /// Best center per row.
    pub best: Vec<u32>,
    /// Similarity to the best center.
    pub best_sim: Vec<f32>,
    /// Similarity to the second-best center.
    pub second_sim: Vec<f32>,
}

#[cfg(feature = "pjrt")]
impl AssignEngine {
    /// Load the artifact for an exact shape from `dir` and compile it.
    pub fn load(dir: &Path, manifest: Manifest) -> Result<Self, EngineError> {
        let path = dir.join(manifest.filename());
        if !path.exists() {
            return Err(EngineError::MissingArtifact(path));
        }
        let client = xla::PjRtClient::cpu()?;
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str()
                .ok_or_else(|| EngineError::Shape("non-utf8 path".into()))?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client.compile(&comp)?;
        Ok(Self {
            client,
            exe,
            manifest,
            stage: vec![0.0; manifest.batch * manifest.dim],
        })
    }

    /// Load the best-matching artifact in `dir` for `k` centers of
    /// dimensionality `dim` (any batch size).
    pub fn load_matching(dir: &Path, k: usize, dim: usize) -> Result<Self, EngineError> {
        let all = list_artifacts(dir)
            .map_err(|_| EngineError::MissingArtifact(dir.to_path_buf()))?;
        let m = all
            .iter()
            .map(|(m, _)| *m)
            .find(|m| m.k == k && m.dim == dim)
            .ok_or_else(|| {
                EngineError::MissingArtifact(dir.join(format!("assign_*_k{k}_d{dim}")))
            })?;
        Self::load(dir, m)
    }

    /// The shape this engine was compiled for.
    pub fn manifest(&self) -> Manifest {
        self.manifest
    }

    /// PJRT platform name (diagnostics).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Execute the assignment step on a dense row-major tile
    /// `x[batch × dim]` against `centers[k × dim]`.
    pub fn assign_dense(
        &self,
        x: &[f32],
        centers: &[f32],
    ) -> Result<AssignTile, EngineError> {
        let m = self.manifest;
        if x.len() != m.batch * m.dim {
            return Err(EngineError::Shape(format!(
                "x has {} elements, expected {}×{}",
                x.len(),
                m.batch,
                m.dim
            )));
        }
        if centers.len() != m.k * m.dim {
            return Err(EngineError::Shape(format!(
                "centers has {} elements, expected {}×{}",
                centers.len(),
                m.k,
                m.dim
            )));
        }
        let xl = xla::Literal::vec1(x).reshape(&[m.batch as i64, m.dim as i64])?;
        let cl = xla::Literal::vec1(centers).reshape(&[m.k as i64, m.dim as i64])?;
        let result = self.exe.execute::<xla::Literal>(&[xl, cl])?[0][0].to_literal_sync()?;
        // aot.py lowers with return_tuple=True: (best_idx i32, best f32, second f32).
        let (t1, t2, t3) = result.to_tuple3()?;
        let best_i32 = t1.to_vec::<i32>()?;
        Ok(AssignTile {
            best: best_i32.into_iter().map(|v| v as u32).collect(),
            best_sim: t2.to_vec::<f32>()?,
            second_sim: t3.to_vec::<f32>()?,
        })
    }

    /// Run the assignment step over all rows of a sparse matrix (densifying
    /// tile by tile), against dense `centers[k × dim]`. The trailing
    /// partial tile is zero-padded; padding rows are discarded.
    pub fn assign_all(
        &mut self,
        data: &CsrMatrix,
        centers: &[f32],
    ) -> Result<AssignTile, EngineError> {
        let m = self.manifest;
        if data.cols() != m.dim {
            return Err(EngineError::Shape(format!(
                "data has {} cols, engine compiled for {}",
                data.cols(),
                m.dim
            )));
        }
        let n = data.rows();
        let mut out = AssignTile {
            best: Vec::with_capacity(n),
            best_sim: Vec::with_capacity(n),
            second_sim: Vec::with_capacity(n),
        };
        let mut start = 0;
        while start < n {
            let end = (start + m.batch).min(n);
            // Densify the tile (zero-padding the tail).
            self.stage.fill(0.0);
            let stage = &mut self.stage;
            for (local, r) in (start..end).enumerate() {
                let row = data.row(r);
                let base = local * m.dim;
                for (t, &c) in row.indices.iter().enumerate() {
                    stage[base + c as usize] = row.values[t];
                }
            }
            let tile = self.assign_dense(&self.stage, centers)?;
            let take = end - start;
            out.best.extend_from_slice(&tile.best[..take]);
            out.best_sim.extend_from_slice(&tile.best_sim[..take]);
            out.second_sim.extend_from_slice(&tile.second_sim[..take]);
            start = end;
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_round_trip() {
        let m = Manifest { batch: 128, k: 16, dim: 512 };
        assert_eq!(m.filename(), "assign_b128_k16_d512.hlo.txt");
        assert_eq!(Manifest::parse(&m.filename()), Some(m));
        assert_eq!(
            Manifest::parse("assign_b1_k2_d3.hlo.txt"),
            Some(Manifest { batch: 1, k: 2, dim: 3 })
        );
        assert!(Manifest::parse("model.hlo.txt").is_none());
        assert!(Manifest::parse("assign_bX_k2_d3.hlo.txt").is_none());
    }

    #[test]
    fn manifest_round_trips_for_all_shapes() {
        crate::util::prop::forall(300, 0xAF01, |g| {
            let m = Manifest {
                batch: g.usize_in(1, 4096),
                k: g.usize_in(1, 2048),
                dim: g.usize_in(1, 1 << 20),
            };
            let parsed = Manifest::parse(&m.filename());
            assert_eq!(parsed, Some(m), "filename {:?}", m.filename());
        });
    }

    #[test]
    fn manifest_rejects_trailing_and_malformed_segments() {
        // Trailing `_k`/`_d` segments must be rejected, not absorbed.
        for bad in [
            "assign_b8_k10_d128_k2.hlo.txt",
            "assign_b8_k10_d128_d64.hlo.txt",
            "assign_b8_k10_d128_extra.hlo.txt",
            "assign_b8_k1_k10_d128.hlo.txt",
            "assign_b8_d128_k10.hlo.txt",
            "assign_b8_k10_d128.hlo.txt.bak",
            "assign_b_k10_d128.hlo.txt",
            "assign_b8_k_d128.hlo.txt",
            "assign_b8_k10_d.hlo.txt",
            // `usize::from_str` would accept these; the strict parser must
            // not — they would re-render to a *different* filename.
            "assign_b+8_k10_d128.hlo.txt",
            "assign_b8_k+10_d128.hlo.txt",
            "assign_b8_k10_d+128.hlo.txt",
            "assign_b08_k10_d128.hlo.txt",
            "assign_b8_k010_d128.hlo.txt",
            "assign_b8_k10_d0128.hlo.txt",
        ] {
            assert_eq!(Manifest::parse(bad), None, "accepted {bad:?}");
        }
    }

    #[test]
    fn artifacts_available_on_missing_dir() {
        assert!(!artifacts_available(Path::new("/nonexistent/surely")));
    }

    // Engine execution tests live in rust/tests/runtime_integration.rs and
    // are skipped when `make artifacts` has not run (and compiled only
    // with the `pjrt` feature).
}
