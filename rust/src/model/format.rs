//! Binary encoder/decoder for the `.spkm` model format (see the
//! [module docs](super) for the layout). Everything is little-endian on
//! every platform; the decoder trusts nothing it has not validated.

use super::{Model, TrainingMeta};
use crate::kmeans::{MiniBatchParams, TrainState};
use crate::sparse::DenseMatrix;

/// Leading magic of every `.spkm` file.
pub(crate) const MAGIC: [u8; 8] = *b"SPHKMDL\0";
/// Serve-only format version: centers + metadata, no training state.
/// State-free models still encode exactly these bytes, so files written
/// by earlier builds are byte-identical to what this build writes.
pub(crate) const VERSION: u32 = 1;
/// State-bearing format version: version 1 plus the resumable
/// [`TrainState`] section (see the [module docs](super)).
pub(crate) const VERSION_STATE: u32 = 2;
/// Ceiling on the dense k×d f32 center matrix a load will reconstruct
/// (16 GiB). The file stores centers sparsely, so a hostile (or corrupt)
/// header can claim a huge `d` with almost no bytes behind it — without
/// this cap, `DenseMatrix::zeros(k, d)` would attempt a multi-TiB
/// allocation and abort instead of returning a typed error. Any model
/// that fits under it is served from that dense matrix anyway.
const MAX_DENSE_BYTES: u128 = 16 << 30;

/// Why a model file was rejected. Every failure mode of
/// [`Model::load`](super::Model::load) is one of these — loading never
/// panics on bad bytes and never returns a silently-wrong model.
#[derive(Debug, thiserror::Error)]
pub enum ModelError {
    /// Underlying filesystem error.
    #[error("io: {0}")]
    Io(#[from] std::io::Error),
    /// The file does not start with the `.spkm` magic — not a model file.
    #[error("not a sphkm model file (bad magic)")]
    BadMagic,
    /// The file was written by a newer format version than this build
    /// understands; guessing at an unknown layout would corrupt silently.
    #[error("unsupported model format version {found} (this build reads ≤ {VERSION_STATE})")]
    UnsupportedVersion {
        /// Version recorded in the file.
        found: u32,
    },
    /// The file ends before the named section is complete.
    #[error("model file truncated in {section}")]
    Truncated {
        /// Which section the decoder was reading when the bytes ran out.
        section: &'static str,
    },
    /// The bytes are structurally wrong: checksum mismatch, trailing
    /// garbage, CSR invariant violations, non-UTF-8 metadata, …
    #[error("corrupt model file: {0}")]
    Corrupt(String),
    /// A model field is too large for the `.spkm` layout's fixed-width
    /// encoding (a center column index beyond `u32`, a metadata string
    /// beyond `u16`). Writing it through a lossy `as` cast would corrupt
    /// the file silently; saving fails with this error instead.
    #[error("{field} = {value} exceeds the .spkm format limit of {max}")]
    FieldOverflow {
        /// Which field overflowed.
        field: &'static str,
        /// The value that did not fit.
        value: u64,
        /// The largest value the layout can represent.
        max: u64,
    },
}

/// FNV-1a 64-bit over `bytes` — the integrity checksum appended to every
/// model file. Not cryptographic; it catches the realistic failure modes
/// (bit rot, partial writes, concatenated/edited files).
fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x1_0000_0000_01b3);
    }
    h
}

/// Encode `model` to the `.spkm` byte layout (version 1 without training
/// state, version 2 with), checksum included. The encoding is a pure
/// function of the model, so identical models produce byte-identical
/// files. Fails with [`ModelError::FieldOverflow`] when a field exceeds
/// the layout's fixed-width encoding instead of truncating it silently.
pub(crate) fn encode(model: &Model) -> Result<Vec<u8>, ModelError> {
    let (k, d) = (model.k(), model.d());
    // Sparse CSR pass over the dense centers: a coordinate is stored iff
    // its f32 bit pattern is non-zero, so -0.0 survives the round trip.
    let mut indptr: Vec<u64> = Vec::with_capacity(k + 1);
    let mut indices: Vec<u32> = Vec::new();
    let mut values: Vec<f32> = Vec::new();
    indptr.push(0);
    for j in 0..k {
        for (c, &v) in model.centers().row(j).iter().enumerate() {
            if v.to_bits() != 0 {
                let c = u32::try_from(c).map_err(|_| ModelError::FieldOverflow {
                    field: "center column index",
                    value: c as u64,
                    max: u32::MAX as u64,
                })?;
                indices.push(c);
                values.push(v);
            }
        }
        indptr.push(indices.len() as u64);
    }
    let meta = model.meta();
    let state = model.state();
    let version = if state.is_some() { VERSION_STATE } else { VERSION };
    let mut buf = Vec::with_capacity(64 + 8 * k + 8 * indices.len());
    buf.extend_from_slice(&MAGIC);
    buf.extend_from_slice(&version.to_le_bytes());
    buf.extend_from_slice(&0u32.to_le_bytes()); // flags (reserved)
    buf.extend_from_slice(&(k as u64).to_le_bytes());
    buf.extend_from_slice(&(d as u64).to_le_bytes());
    buf.extend_from_slice(&(indices.len() as u64).to_le_bytes());
    buf.extend_from_slice(&meta.iterations.to_le_bytes());
    buf.extend_from_slice(&meta.seed.to_le_bytes());
    buf.extend_from_slice(&meta.objective.to_bits().to_le_bytes());
    for s in [&meta.variant, &meta.kernel] {
        let bytes = s.as_bytes();
        let len = u16::try_from(bytes.len()).map_err(|_| ModelError::FieldOverflow {
            field: "metadata string length",
            value: bytes.len() as u64,
            max: u16::MAX as u64,
        })?;
        buf.extend_from_slice(&len.to_le_bytes());
        buf.extend_from_slice(bytes);
    }
    for &n in model.norms() {
        buf.extend_from_slice(&n.to_bits().to_le_bytes());
    }
    for &p in &indptr {
        buf.extend_from_slice(&p.to_le_bytes());
    }
    for &i in &indices {
        buf.extend_from_slice(&i.to_le_bytes());
    }
    for &v in &values {
        buf.extend_from_slice(&v.to_bits().to_le_bytes());
    }
    if let Some(state) = state {
        buf.extend_from_slice(&state.steps_done.to_le_bytes());
        buf.push(u8::from(state.converged));
        buf.extend_from_slice(&(state.assignments.len() as u64).to_le_bytes());
        for &a in &state.assignments {
            buf.extend_from_slice(&a.to_le_bytes());
        }
        for &c in &state.counts {
            buf.extend_from_slice(&c.to_le_bytes());
        }
        for &s in &state.sums {
            buf.extend_from_slice(&s.to_bits().to_le_bytes());
        }
        // Mini-batch schedule the state was trained under (flag byte,
        // then the four knobs; truncate stores 0 for None — Some(0) is
        // rejected at fit time, so the encoding is unambiguous).
        match &state.minibatch {
            None => buf.push(0),
            Some(p) => {
                buf.push(1);
                buf.extend_from_slice(&(p.batch_size as u64).to_le_bytes());
                buf.extend_from_slice(&(p.epochs as u64).to_le_bytes());
                buf.extend_from_slice(&p.tol.to_bits().to_le_bytes());
                buf.extend_from_slice(&(p.truncate.unwrap_or(0) as u64).to_le_bytes());
            }
        }
    }
    let sum = fnv1a(&buf);
    buf.extend_from_slice(&sum.to_le_bytes());
    Ok(buf)
}

/// Streaming counterpart of [`Cur`] for [`decode_low_mem`]: reads from
/// any [`Read`](std::io::Read) while folding every byte into an
/// incremental FNV-1a-64, so the checksum can be verified without ever
/// holding the file in memory. Truncation surfaces as the same typed
/// [`ModelError::Truncated`] the in-memory decoder reports.
struct HashRead<R> {
    inner: R,
    hash: u64,
    /// Total bytes consumed (hashed or raw) — for trailing-byte checks.
    consumed: u64,
}

impl<R: std::io::Read> HashRead<R> {
    fn new(inner: R) -> Self {
        Self { inner, hash: 0xcbf2_9ce4_8422_2325, consumed: 0 }
    }

    /// Read exactly `buf.len()` bytes and fold them into the checksum.
    fn fill(&mut self, buf: &mut [u8], section: &'static str) -> Result<(), ModelError> {
        self.fill_raw(buf, section)?;
        for &b in buf.iter() {
            self.hash ^= b as u64;
            self.hash = self.hash.wrapping_mul(0x1_0000_0000_01b3);
        }
        Ok(())
    }

    /// Read exactly `buf.len()` bytes *without* hashing them — only the
    /// trailing checksum itself is read this way.
    fn fill_raw(&mut self, buf: &mut [u8], section: &'static str) -> Result<(), ModelError> {
        self.inner.read_exact(buf).map_err(|e| match e.kind() {
            std::io::ErrorKind::UnexpectedEof => ModelError::Truncated { section },
            _ => ModelError::Io(e),
        })?;
        self.consumed += buf.len() as u64;
        Ok(())
    }

    fn byte(&mut self, section: &'static str) -> Result<u8, ModelError> {
        let mut b = [0u8; 1];
        self.fill(&mut b, section)?;
        Ok(b[0])
    }

    fn u16(&mut self, section: &'static str) -> Result<u16, ModelError> {
        let mut b = [0u8; 2];
        self.fill(&mut b, section)?;
        Ok(u16::from_le_bytes(b))
    }

    fn u32(&mut self, section: &'static str) -> Result<u32, ModelError> {
        let mut b = [0u8; 4];
        self.fill(&mut b, section)?;
        Ok(u32::from_le_bytes(b))
    }

    fn u64(&mut self, section: &'static str) -> Result<u64, ModelError> {
        let mut b = [0u8; 8];
        self.fill(&mut b, section)?;
        Ok(u64::from_le_bytes(b))
    }

    fn string(&mut self, section: &'static str) -> Result<String, ModelError> {
        let len = self.u16(section)? as usize;
        let mut bytes = vec![0u8; len];
        self.fill(&mut bytes, section)?;
        String::from_utf8(bytes)
            .map_err(|_| ModelError::Corrupt(format!("{section} is not UTF-8")))
    }

    /// Consume (and hash) `n` bytes in bounded 64 KiB steps — how the
    /// low-memory loader walks past the training-state arrays it does not
    /// materialize while keeping the whole-file checksum honest.
    fn skip(&mut self, mut n: u64, section: &'static str) -> Result<(), ModelError> {
        let mut chunk = vec![0u8; 64 * 1024];
        while n > 0 {
            let take = usize::try_from(n.min(chunk.len() as u64)).expect("≤ 64 KiB");
            self.fill(&mut chunk[..take], section)?;
            n -= take as u64;
        }
        Ok(())
    }
}

/// Low-memory streaming decode of a `.spkm` file: the same validation
/// order and rejection taxonomy as [`decode`], but the file is never
/// materialized as one buffer and the version-2 training-state section —
/// the dominant cost for large corpora (`4·n` assignment bytes plus
/// `8·k·d` sum bytes) — is checksummed and *skipped*, never allocated.
/// Peak transient memory is `O(k·d)` (the dense centers plus one `u32`
/// index per stored coordinate) regardless of file size; the returned
/// model is serve-only (`state() == None`), so the per-state sanity
/// checks of the in-memory decoder do not apply to it.
pub(crate) fn decode_low_mem(path: &std::path::Path) -> Result<Model, ModelError> {
    let file = std::fs::File::open(path)?;
    let total = file.metadata()?.len();
    let mut r = HashRead::new(std::io::BufReader::new(file));
    let mut magic = [0u8; 8];
    r.fill(&mut magic, "magic")?;
    if magic != MAGIC {
        return Err(ModelError::BadMagic);
    }
    let version = r.u32("version")?;
    if version != VERSION && version != VERSION_STATE {
        return Err(ModelError::UnsupportedVersion { found: version });
    }
    let has_state = version == VERSION_STATE;
    let flags = r.u32("flags")?;
    if flags != 0 {
        return Err(ModelError::Corrupt(format!("reserved flags set: {flags:#x}")));
    }
    let k = checked_dim(r.u64("shape")?, "k", 1 << 32)?;
    let d = checked_dim(r.u64("shape")?, "d", 1 << 40)?;
    if 4 * k as u128 * d as u128 > MAX_DENSE_BYTES {
        return Err(ModelError::Corrupt(format!(
            "dense {k}×{d} centers would exceed the {} GiB reconstruction cap",
            MAX_DENSE_BYTES >> 30
        )));
    }
    let nnz = checked_dim(r.u64("shape")?, "nnz", (k as u64).saturating_mul(d as u64))?;
    let iterations = r.u64("training metadata")?;
    let seed = r.u64("training metadata")?;
    let objective = f64::from_bits(r.u64("training metadata")?);
    let variant = r.string("variant name")?;
    let kernel = r.string("kernel name")?;
    // Same up-front accounting as the in-memory decoder, against the file
    // length instead of a buffer: a corrupt header claiming a huge k or
    // nnz must report Truncated before driving a giant allocation.
    let needed = 8u128 * k as u128 + 8 * (k as u128 + 1) + 8 * nnz as u128 + 8;
    if needed > (total as u128).saturating_sub(r.consumed as u128) {
        return Err(ModelError::Truncated { section: "center arrays" });
    }
    let mut norms = Vec::with_capacity(k);
    for _ in 0..k {
        norms.push(f64::from_bits(r.u64("norms")?));
    }
    if let Some(j) = norms.iter().position(|n| !n.is_finite()) {
        return Err(ModelError::Corrupt(format!("non-finite norm for center {j}")));
    }
    let mut indptr = Vec::with_capacity(k + 1);
    for _ in 0..=k {
        indptr.push(r.u64("indptr")?);
    }
    if indptr[0] != 0 || indptr[k] != nnz as u64 {
        return Err(ModelError::Corrupt(format!(
            "indptr endpoints [{}, {}] do not match nnz {nnz}",
            indptr[0], indptr[k]
        )));
    }
    if let Some(w) = indptr.windows(2).find(|w| w[0] > w[1]) {
        return Err(ModelError::Corrupt(format!(
            "indptr not monotone ({} before {})",
            w[0], w[1]
        )));
    }
    // Lossless: the endpoint/monotonicity checks cap every entry at nnz.
    let ptr: Vec<usize> = indptr
        .iter()
        .map(|&p| usize::try_from(p).expect("indptr bounded by nnz"))
        .collect();
    // Indices are buffered (4 bytes per stored coordinate) and validated
    // per row; values then stream straight into the dense matrix.
    let mut indices = Vec::with_capacity(nnz);
    for _ in 0..nnz {
        indices.push(r.u32("indices")?);
    }
    for j in 0..k {
        let mut prev: Option<u32> = None;
        for &c in &indices[ptr[j]..ptr[j + 1]] {
            if prev.is_some_and(|p| p >= c) {
                return Err(ModelError::Corrupt(format!(
                    "center {j}: indices not strictly increasing at {c}"
                )));
            }
            if c as usize >= d {
                return Err(ModelError::Corrupt(format!(
                    "center {j}: index {c} out of bounds for d = {d}"
                )));
            }
            prev = Some(c);
        }
    }
    let mut centers = DenseMatrix::zeros(k, d);
    {
        let mut j = 0usize;
        for (t, &c) in indices.iter().enumerate() {
            let v = f32::from_bits(r.u32("values")?);
            if !v.is_finite() {
                return Err(ModelError::Corrupt(format!("non-finite center value at nnz {t}")));
            }
            if v.to_bits() == 0 {
                return Err(ModelError::Corrupt(format!(
                    "explicit +0.0 coordinate stored at nnz {t} (non-canonical encoding)"
                )));
            }
            while ptr[j + 1] <= t {
                j += 1;
            }
            centers.row_mut(j)[c as usize] = v;
        }
    }
    if has_state {
        // Structural walk of the state section: fixed-width prefix, then
        // the variable-length arrays are hashed and discarded.
        let _steps_done = r.u64("training state")?;
        match r.byte("training state")? {
            0 | 1 => {}
            other => {
                return Err(ModelError::Corrupt(format!(
                    "converged flag must be 0 or 1, got {other}"
                )))
            }
        }
        let n = checked_dim(r.u64("training state")?, "state rows", 1 << 40)?;
        let body = 4u128 * n as u128 + 8 * k as u128 + 8 * (k as u128 * d as u128);
        if body + 8 > (total as u128).saturating_sub(r.consumed as u128) {
            return Err(ModelError::Truncated { section: "training state" });
        }
        r.skip(
            u64::try_from(body).expect("bounded by the file length"),
            "training state",
        )?;
        match r.byte("state schedule")? {
            0 => {}
            1 => r.skip(32, "state schedule")?,
            other => {
                return Err(ModelError::Corrupt(format!(
                    "state schedule flag must be 0 or 1, got {other}"
                )))
            }
        }
    }
    let computed = r.hash;
    let mut sum = [0u8; 8];
    r.fill_raw(&mut sum, "checksum")?;
    let stored_sum = u64::from_le_bytes(sum);
    if r.consumed != total {
        return Err(ModelError::Corrupt(format!(
            "{} trailing bytes after checksum",
            total - r.consumed
        )));
    }
    if stored_sum != computed {
        return Err(ModelError::Corrupt(format!(
            "checksum mismatch (stored {stored_sum:#018x}, computed {computed:#018x})"
        )));
    }
    Ok(Model::from_parts(
        k,
        d,
        centers,
        norms,
        nnz,
        TrainingMeta { variant, kernel, iterations, objective, seed },
        None,
    ))
}

/// A bounds-checked cursor over the raw file bytes: every read names the
/// section it serves so truncation errors point at the failure site.
struct Cur<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cur<'a> {
    fn take(&mut self, n: usize, section: &'static str) -> Result<&'a [u8], ModelError> {
        if self.buf.len() - self.pos < n {
            return Err(ModelError::Truncated { section });
        }
        let out = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(out)
    }

    fn u16(&mut self, section: &'static str) -> Result<u16, ModelError> {
        Ok(u16::from_le_bytes(self.take(2, section)?.try_into().unwrap()))
    }

    fn u32(&mut self, section: &'static str) -> Result<u32, ModelError> {
        Ok(u32::from_le_bytes(self.take(4, section)?.try_into().unwrap()))
    }

    fn u64(&mut self, section: &'static str) -> Result<u64, ModelError> {
        Ok(u64::from_le_bytes(self.take(8, section)?.try_into().unwrap()))
    }

    fn string(&mut self, section: &'static str) -> Result<String, ModelError> {
        let len = self.u16(section)? as usize;
        let bytes = self.take(len, section)?;
        String::from_utf8(bytes.to_vec())
            .map_err(|_| ModelError::Corrupt(format!("{section} is not UTF-8")))
    }
}

/// Decoded `usize` that must fit the platform and a sanity ceiling.
fn checked_dim(v: u64, what: &str, cap: u64) -> Result<usize, ModelError> {
    if v > cap {
        return Err(ModelError::Corrupt(format!("{what} {v} is implausibly large")));
    }
    usize::try_from(v).map_err(|_| {
        ModelError::Corrupt(format!("{what} {v} does not fit this platform's address space"))
    })
}

/// Decode a full `.spkm` byte buffer into a [`Model`], validating in
/// order: magic → version → structure (typed truncation errors) → no
/// trailing bytes → checksum → CSR invariants. Version is checked before
/// the checksum so files from future versions report
/// [`ModelError::UnsupportedVersion`] rather than a layout-dependent
/// checksum mismatch.
pub(crate) fn decode(buf: &[u8]) -> Result<Model, ModelError> {
    let mut cur = Cur { buf, pos: 0 };
    if cur.take(8, "magic")? != MAGIC {
        return Err(ModelError::BadMagic);
    }
    let version = cur.u32("version")?;
    if version != VERSION && version != VERSION_STATE {
        return Err(ModelError::UnsupportedVersion { found: version });
    }
    let has_state = version == VERSION_STATE;
    let flags = cur.u32("flags")?;
    if flags != 0 {
        return Err(ModelError::Corrupt(format!("reserved flags set: {flags:#x}")));
    }
    // Shape caps keep a corrupt header from driving a huge allocation
    // before the checksum has had a chance to reject the file.
    let k = checked_dim(cur.u64("shape")?, "k", 1 << 32)?;
    let d = checked_dim(cur.u64("shape")?, "d", 1 << 40)?;
    if 4 * k as u128 * d as u128 > MAX_DENSE_BYTES {
        return Err(ModelError::Corrupt(format!(
            "dense {k}×{d} centers would exceed the {} GiB reconstruction cap",
            MAX_DENSE_BYTES >> 30
        )));
    }
    let nnz = checked_dim(cur.u64("shape")?, "nnz", (k as u64).saturating_mul(d as u64))?;
    let iterations = cur.u64("training metadata")?;
    let seed = cur.u64("training metadata")?;
    let objective = f64::from_bits(cur.u64("training metadata")?);
    let variant = cur.string("variant name")?;
    let kernel = cur.string("kernel name")?;
    // Size the remainder up front so a corrupt header claiming a huge k or
    // nnz reports Truncated instead of attempting a giant allocation: the
    // arrays below must all fit in the bytes that are actually present.
    // norms + indptr + (indices + values) + checksum, in u128 so a
    // hostile header cannot overflow the accounting itself. (The
    // variable-length version-2 state section accounts for itself the
    // same way once its row count is known.)
    let needed = 8u128 * k as u128 + 8 * (k as u128 + 1) + 8 * nnz as u128 + 8;
    if needed > (buf.len() - cur.pos) as u128 {
        return Err(ModelError::Truncated { section: "center arrays" });
    }
    let mut norms = Vec::with_capacity(k);
    for _ in 0..k {
        norms.push(f64::from_bits(cur.u64("norms")?));
    }
    let mut indptr = Vec::with_capacity(k + 1);
    for _ in 0..=k {
        indptr.push(cur.u64("indptr")?);
    }
    let mut indices = Vec::with_capacity(nnz);
    for _ in 0..nnz {
        indices.push(cur.u32("indices")?);
    }
    let mut values = Vec::with_capacity(nnz);
    for _ in 0..nnz {
        values.push(f32::from_bits(cur.u32("values")?));
    }
    let state = if has_state {
        let steps_done = cur.u64("training state")?;
        let converged = match cur.take(1, "training state")?[0] {
            0 => false,
            1 => true,
            other => {
                return Err(ModelError::Corrupt(format!(
                    "converged flag must be 0 or 1, got {other}"
                )))
            }
        };
        let n = checked_dim(cur.u64("training state")?, "state rows", 1 << 40)?;
        // Up-front size accounting for the variable-length section, as for
        // the center arrays above: assignments + counts + sums + checksum.
        let needed =
            4u128 * n as u128 + 8 * k as u128 + 8 * (k as u128 * d as u128) + 8;
        if needed > (buf.len() - cur.pos) as u128 {
            return Err(ModelError::Truncated { section: "training state" });
        }
        let mut assignments = Vec::with_capacity(n);
        for _ in 0..n {
            assignments.push(cur.u32("state assignments")?);
        }
        let mut counts = Vec::with_capacity(k);
        for _ in 0..k {
            counts.push(cur.u64("state counts")?);
        }
        let mut sums = Vec::with_capacity(k * d);
        for _ in 0..k * d {
            sums.push(f64::from_bits(cur.u64("state sums")?));
        }
        let minibatch = match cur.take(1, "state schedule")?[0] {
            0 => None,
            1 => {
                let batch_size =
                    checked_dim(cur.u64("state schedule")?, "batch_size", 1 << 40)?;
                let epochs = checked_dim(cur.u64("state schedule")?, "epochs", 1 << 40)?;
                let tol = f64::from_bits(cur.u64("state schedule")?);
                let truncate = checked_dim(cur.u64("state schedule")?, "truncate", 1 << 40)?;
                if batch_size == 0 {
                    return Err(ModelError::Corrupt("state batch_size is 0".into()));
                }
                if !tol.is_finite() || tol < 0.0 {
                    return Err(ModelError::Corrupt(format!(
                        "state tol {tol} is not a valid tolerance"
                    )));
                }
                Some(MiniBatchParams {
                    batch_size,
                    epochs,
                    tol,
                    truncate: if truncate == 0 { None } else { Some(truncate) },
                })
            }
            other => {
                return Err(ModelError::Corrupt(format!(
                    "state schedule flag must be 0 or 1, got {other}"
                )))
            }
        };
        Some(TrainState { steps_done, converged, assignments, counts, sums, minibatch })
    } else {
        None
    };
    let stored_sum = u64::from_le_bytes(
        cur.take(8, "checksum")?
            .try_into()
            .expect("checksum slice is 8 bytes"),
    );
    if cur.pos != buf.len() {
        return Err(ModelError::Corrupt(format!(
            "{} trailing bytes after checksum",
            buf.len() - cur.pos
        )));
    }
    let computed = fnv1a(&buf[..buf.len() - 8]);
    if stored_sum != computed {
        return Err(ModelError::Corrupt(format!(
            "checksum mismatch (stored {stored_sum:#018x}, computed {computed:#018x})"
        )));
    }
    // Payload sanity: a NaN/infinite center coordinate or norm would not
    // fail here but would panic the serving comparators on the very first
    // query — reject it at the boundary like every other corruption.
    if let Some(i) = values.iter().position(|v| !v.is_finite()) {
        return Err(ModelError::Corrupt(format!("non-finite center value at nnz {i}")));
    }
    // The encoder never stores a +0.0 (zero-bit) coordinate; accepting one
    // would make the header nnz disagree with the reconstructed matrix's
    // non-zero count and break the deterministic re-encoding guarantee.
    if let Some(i) = values.iter().position(|v| v.to_bits() == 0) {
        return Err(ModelError::Corrupt(format!(
            "explicit +0.0 coordinate stored at nnz {i} (non-canonical encoding)"
        )));
    }
    if let Some(j) = norms.iter().position(|n| !n.is_finite()) {
        return Err(ModelError::Corrupt(format!("non-finite norm for center {j}")));
    }
    if let Some(state) = &state {
        // Training-state sanity: every assignment must name an existing
        // cluster and every sum accumulator must be a finite number — a
        // resumed run would otherwise corrupt silently or panic later.
        if let Some(i) = state.assignments.iter().position(|&a| a as usize >= k) {
            return Err(ModelError::Corrupt(format!(
                "state assignment {} at row {i} out of bounds for k = {k}",
                state.assignments[i]
            )));
        }
        if let Some(i) = state.sums.iter().position(|s| !s.is_finite()) {
            return Err(ModelError::Corrupt(format!(
                "non-finite state sum at coordinate {i}"
            )));
        }
    }
    // CSR invariants: monotone indptr ending at nnz; strictly increasing
    // in-bounds indices per row.
    if indptr[0] != 0 || indptr[k] != nnz as u64 {
        return Err(ModelError::Corrupt(format!(
            "indptr endpoints [{}, {}] do not match nnz {nnz}",
            indptr[0], indptr[k]
        )));
    }
    if let Some(w) = indptr.windows(2).find(|w| w[0] > w[1]) {
        return Err(ModelError::Corrupt(format!(
            "indptr not monotone ({} before {})",
            w[0], w[1]
        )));
    }
    let mut centers = DenseMatrix::zeros(k, d);
    for j in 0..k {
        // Lossless: the endpoint/monotonicity checks above cap every
        // indptr entry at nnz, which is already a usize.
        let s = usize::try_from(indptr[j]).expect("indptr bounded by nnz");
        let e = usize::try_from(indptr[j + 1]).expect("indptr bounded by nnz");
        let row = centers.row_mut(j);
        let mut prev: Option<u32> = None;
        for t in s..e {
            let c = indices[t];
            if prev.is_some_and(|p| p >= c) {
                return Err(ModelError::Corrupt(format!(
                    "center {j}: indices not strictly increasing at {c}"
                )));
            }
            if c as usize >= d {
                return Err(ModelError::Corrupt(format!(
                    "center {j}: index {c} out of bounds for d = {d}"
                )));
            }
            prev = Some(c);
            row[c as usize] = values[t];
        }
    }
    Ok(Model::from_parts(
        k,
        d,
        centers,
        norms,
        nnz,
        TrainingMeta { variant, kernel, iterations, objective, seed },
        state,
    ))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy_model() -> Model {
        let centers = DenseMatrix::from_vec(2, 3, vec![0.6, 0.0, 0.8, 0.0, -1.0, 0.0]);
        Model::new(
            centers,
            TrainingMeta {
                variant: "Standard".into(),
                kernel: "gather".into(),
                iterations: 4,
                objective: 1.25,
                seed: 42,
            },
        )
    }

    #[test]
    fn encode_decode_round_trips_bitwise() {
        let m = toy_model();
        let bytes = encode(&m).unwrap();
        let back = decode(&bytes).unwrap();
        assert_eq!(back, m);
        // Deterministic encoding.
        assert_eq!(encode(&back).unwrap(), bytes);
    }

    #[test]
    fn oversized_metadata_string_is_a_typed_overflow() {
        let centers = DenseMatrix::from_vec(1, 2, vec![0.6, 0.8]);
        let m = Model::new(
            centers,
            TrainingMeta {
                variant: "v".repeat(usize::from(u16::MAX) + 1),
                kernel: "gather".into(),
                iterations: 0,
                objective: 0.0,
                seed: 0,
            },
        );
        let err = encode(&m).unwrap_err();
        assert!(
            matches!(err, ModelError::FieldOverflow { field: "metadata string length", .. }),
            "{err}"
        );
    }

    #[test]
    fn negative_zero_coordinates_survive() {
        let mut centers = DenseMatrix::zeros(1, 2);
        centers.row_mut(0)[0] = -0.0;
        centers.row_mut(0)[1] = 1.0;
        let m = Model::new(
            centers,
            TrainingMeta {
                variant: "x".into(),
                kernel: "y".into(),
                iterations: 0,
                objective: 0.0,
                seed: 0,
            },
        );
        assert_eq!(m.center_nnz(), 2, "-0.0 has a non-zero bit pattern");
        let back = decode(&encode(&m).unwrap()).unwrap();
        assert_eq!(back.centers().row(0)[0].to_bits(), (-0.0f32).to_bits());
    }

    #[test]
    fn state_bearing_models_round_trip_as_version_2() {
        let state = TrainState {
            steps_done: 3,
            converged: true,
            assignments: vec![0, 1, 1],
            counts: vec![1, 2],
            sums: vec![0.5, -0.25, 0.0, 1.5, 0.0, 2.0],
            minibatch: Some(MiniBatchParams {
                batch_size: 256,
                epochs: 7,
                tol: 1e-3,
                truncate: Some(16),
            }),
        };
        let m = toy_model().with_state(Some(state));
        let bytes = encode(&m).unwrap();
        assert_eq!(&bytes[8..12], &2u32.to_le_bytes(), "state ⇒ version 2");
        let back = decode(&bytes).unwrap();
        assert_eq!(back, m);
        assert_eq!(encode(&back).unwrap(), bytes, "deterministic encoding");
        // Stateless models keep writing byte-stable version-1 files.
        let v1 = encode(&toy_model()).unwrap();
        assert_eq!(&v1[8..12], &1u32.to_le_bytes());
        assert!(decode(&v1).unwrap().state().is_none());
        // Truncating inside the state section is a typed error.
        for cut in [v1.len(), v1.len() + 5, bytes.len() - 9] {
            assert!(matches!(
                decode(&bytes[..cut]),
                Err(ModelError::Truncated { .. })
            ));
        }
        // An out-of-bounds state assignment (valid checksum) is Corrupt.
        let mut bad = encode(&toy_model().with_state(Some(TrainState {
            steps_done: 0,
            converged: false,
            assignments: vec![9, 0, 0],
            counts: vec![1, 2],
            sums: vec![0.0; 6],
            minibatch: None,
        })))
        .unwrap();
        let body_end = bad.len() - 8;
        let sum = fnv1a(&bad[..body_end]);
        bad[body_end..].copy_from_slice(&sum.to_le_bytes());
        let err = decode(&bad).unwrap_err();
        assert!(
            matches!(&err, ModelError::Corrupt(msg) if msg.contains("out of bounds")),
            "{err}"
        );
    }

    #[test]
    fn low_mem_load_matches_in_memory_load() {
        let state = TrainState {
            steps_done: 5,
            converged: false,
            assignments: vec![1, 0, 1],
            counts: vec![1, 2],
            sums: vec![0.25, -0.5, 0.0, 1.0, 0.0, -2.0],
            minibatch: Some(MiniBatchParams {
                batch_size: 64,
                epochs: 3,
                tol: 1e-4,
                truncate: None,
            }),
        };
        let m = toy_model().with_state(Some(state));
        let dir = std::env::temp_dir();
        let path = dir.join(format!("sphkm-lowmem-{}.spkm", std::process::id()));
        std::fs::write(&path, encode(&m).unwrap()).unwrap();
        // Streaming load: state skipped, everything else bit-identical.
        let low = decode_low_mem(&path).unwrap();
        assert!(low.state().is_none(), "low-mem loads are serve-only");
        assert_eq!(low.centers(), m.centers());
        assert_eq!(low.norms(), m.norms());
        assert_eq!(low.meta(), m.meta());
        assert_eq!(low.center_nnz(), m.center_nnz());
        // Version-1 (stateless) files decode identically through both.
        let v1 = toy_model();
        std::fs::write(&path, encode(&v1).unwrap()).unwrap();
        assert_eq!(decode_low_mem(&path).unwrap(), v1);
        // The streaming decoder rejects the same failure modes: a flipped
        // body byte (checksum), a truncated file, bad magic.
        let good = encode(&m).unwrap();
        let mut flipped = good.clone();
        let mid = good.len() / 2;
        flipped[mid] ^= 0x01;
        std::fs::write(&path, &flipped).unwrap();
        assert!(matches!(decode_low_mem(&path), Err(ModelError::Corrupt(_))));
        std::fs::write(&path, &good[..good.len() - 3]).unwrap();
        assert!(matches!(
            decode_low_mem(&path),
            Err(ModelError::Truncated { .. })
        ));
        let mut bad = good.clone();
        bad[0] ^= 0xFF;
        std::fs::write(&path, &bad).unwrap();
        assert!(matches!(decode_low_mem(&path), Err(ModelError::BadMagic)));
        // Trailing garbage is rejected.
        let mut padded = good.clone();
        padded.push(0);
        std::fs::write(&path, &padded).unwrap();
        assert!(matches!(decode_low_mem(&path), Err(ModelError::Corrupt(_))));
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn rejects_bad_magic_version_truncation_and_corruption() {
        let good = encode(&toy_model()).unwrap();
        // Bad magic.
        let mut bad = good.clone();
        bad[0] ^= 0xFF;
        assert!(matches!(decode(&bad), Err(ModelError::BadMagic)));
        // Future version (checked before the checksum).
        let mut future = good.clone();
        future[8..12].copy_from_slice(&99u32.to_le_bytes());
        assert!(matches!(
            decode(&future),
            Err(ModelError::UnsupportedVersion { found: 99 })
        ));
        // Truncation at every prefix length must be a typed error.
        for cut in [0, 4, 11, 17, 40, good.len() / 2, good.len() - 1] {
            let err = decode(&good[..cut]).unwrap_err();
            assert!(
                matches!(err, ModelError::Truncated { .. } | ModelError::BadMagic),
                "cut at {cut}: {err}"
            );
        }
        // A flipped body byte breaks the checksum.
        let mut flipped = good.clone();
        let mid = good.len() - 12; // inside the values section
        flipped[mid] ^= 0x01;
        assert!(matches!(decode(&flipped), Err(ModelError::Corrupt(_))));
        // A hostile header claiming a huge d (with a recomputed, valid
        // checksum) must be rejected with a typed error before any
        // dense-reconstruction allocation is attempted.
        let mut huge = good.clone();
        huge[24..32].copy_from_slice(&(1u64 << 39).to_le_bytes()); // d
        let body_end = huge.len() - 8;
        let sum = fnv1a(&huge[..body_end]);
        huge[body_end..].copy_from_slice(&sum.to_le_bytes());
        let err = decode(&huge).unwrap_err();
        assert!(
            matches!(&err, ModelError::Corrupt(msg) if msg.contains("reconstruction cap")),
            "{err}"
        );
        // A checksum-valid file carrying a NaN center value must be
        // rejected at load, not panic the first query.
        let mut nan = good.clone();
        let val_at = good.len() - 12; // last f32 of the values section
        nan[val_at..val_at + 4].copy_from_slice(&f32::NAN.to_bits().to_le_bytes());
        let body_end = nan.len() - 8;
        let sum = fnv1a(&nan[..body_end]);
        nan[body_end..].copy_from_slice(&sum.to_le_bytes());
        let err = decode(&nan).unwrap_err();
        assert!(
            matches!(&err, ModelError::Corrupt(msg) if msg.contains("non-finite")),
            "{err}"
        );
        // Trailing garbage is rejected.
        let mut padded = good.clone();
        padded.push(0);
        assert!(matches!(decode(&padded), Err(ModelError::Corrupt(_))));
    }
}
