//! Structured JSONL trace writer and validator.
//!
//! A trace is a sequence of newline-delimited JSON records, one per
//! line, each a flat object stamped with the schema identifier
//! ([`TRACE_SCHEMA`]) and an `event` discriminator:
//!
//! * `run_start` — once, first line: `algo`, `k`, `n`, `d`, `threads`,
//!   plus any extra configuration the producer attaches.
//! * `iter` — once per training iteration (or mini-batch epoch):
//!   `iteration`, `wall_ms`, `elapsed_ms`, per-phase millisecond
//!   breakdown under `phases`, the instrumentation counters, and
//!   `converged`.
//! * `run_end` — once, last line: `iterations`, `objective`,
//!   `total_ms`, run-level `phases` totals.
//!
//! Producers only append fields; removing or re-typing one is a schema
//! version bump. [`validate_line`] / [`validate_trace`] enforce the
//! envelope (schema stamp, known event, required typed fields) and are
//! what `sphkm report --check` and the `tests/obs.rs` round-trip run.
//! The CLI side lives behind `cluster --trace-out`, which requires the
//! `trace` cargo feature (without it the spans a trace would report are
//! compile-time no-ops).

use std::io::{BufWriter, Write};
use std::path::Path;

use crate::util::json::Json;

/// Schema identifier stamped into every trace record; bump on any
/// breaking record-shape change.
pub const TRACE_SCHEMA: &str = "sphkm.trace.v1";

/// The three record kinds of a v1 trace, in emission order.
pub const TRACE_EVENTS: [&str; 3] = ["run_start", "iter", "run_end"];

/// Append-only JSONL trace writer. Each record lands as one line; the
/// file is flushed on drop (and explicitly by [`TraceWriter::finish`]).
#[derive(Debug)]
pub struct TraceWriter {
    out: BufWriter<std::fs::File>,
    records: usize,
}

impl TraceWriter {
    /// Create (truncate) the trace file at `path`.
    pub fn create(path: &Path) -> std::io::Result<Self> {
        Ok(Self { out: BufWriter::new(std::fs::File::create(path)?), records: 0 })
    }

    /// Append one record: the schema stamp and `event` discriminator,
    /// then `fields` in order.
    pub fn record(
        &mut self,
        event: &str,
        fields: Vec<(String, Json)>,
    ) -> std::io::Result<()> {
        let mut members = vec![
            ("schema".to_string(), Json::Str(TRACE_SCHEMA.to_string())),
            ("event".to_string(), Json::Str(event.to_string())),
        ];
        members.extend(fields);
        let line = Json::Obj(members).render();
        debug_assert!(validate_line(&line).is_ok(), "emitting invalid trace record: {line}");
        self.out.write_all(line.as_bytes())?;
        self.out.write_all(b"\n")?;
        self.records += 1;
        Ok(())
    }

    /// Number of records written so far.
    pub fn records(&self) -> usize {
        self.records
    }

    /// Flush buffered records to disk.
    pub fn finish(&mut self) -> std::io::Result<()> {
        self.out.flush()
    }
}

fn require_num(doc: &Json, key: &str) -> Result<(), String> {
    doc.get(key)
        .and_then(Json::as_f64)
        .map(|_| ())
        .ok_or_else(|| format!("missing numeric field {key:?}"))
}

fn require_phases(doc: &Json) -> Result<(), String> {
    let phases = doc
        .get("phases")
        .and_then(Json::as_obj)
        .ok_or("missing object field \"phases\"")?;
    let known = super::span::Phase::ALL;
    for (k, v) in phases {
        if !known.iter().any(|p| p.name() == k) {
            return Err(format!("unknown phase {k:?}"));
        }
        v.as_f64().ok_or_else(|| format!("phase {k:?} must be numeric (ms)"))?;
    }
    Ok(())
}

/// Validate one trace line against the v1 schema: parses as an object,
/// carries the schema stamp and a known `event`, and has that event's
/// required typed fields.
pub fn validate_line(line: &str) -> Result<(), String> {
    let doc = Json::parse(line).map_err(|e| e.to_string())?;
    if doc.as_obj().is_none() {
        return Err("trace record must be a JSON object".to_string());
    }
    let schema = doc
        .get("schema")
        .and_then(Json::as_str)
        .ok_or("missing string field \"schema\"")?;
    if schema != TRACE_SCHEMA {
        return Err(format!("schema {schema:?}, expected {TRACE_SCHEMA:?}"));
    }
    let event = doc
        .get("event")
        .and_then(Json::as_str)
        .ok_or("missing string field \"event\"")?;
    match event {
        "run_start" => {
            doc.get("algo")
                .and_then(Json::as_str)
                .ok_or("run_start: missing string field \"algo\"")?;
            for key in ["k", "n", "d", "threads"] {
                require_num(&doc, key).map_err(|e| format!("run_start: {e}"))?;
            }
        }
        "iter" => {
            for key in ["iteration", "wall_ms", "elapsed_ms", "sims_point_center", "reassignments"]
            {
                require_num(&doc, key).map_err(|e| format!("iter: {e}"))?;
            }
            doc.get("converged")
                .and_then(Json::as_bool)
                .ok_or("iter: missing boolean field \"converged\"")?;
            require_phases(&doc).map_err(|e| format!("iter: {e}"))?;
        }
        "run_end" => {
            for key in ["iterations", "objective", "total_ms"] {
                require_num(&doc, key).map_err(|e| format!("run_end: {e}"))?;
            }
            require_phases(&doc).map_err(|e| format!("run_end: {e}"))?;
        }
        other => return Err(format!("unknown event {other:?}")),
    }
    Ok(())
}

/// Validate a whole trace document: every line valid, exactly one
/// `run_start` (first) and at most one `run_end` (last). Returns the
/// record count.
pub fn validate_trace(text: &str) -> Result<usize, String> {
    let lines: Vec<&str> = text.lines().filter(|l| !l.trim().is_empty()).collect();
    if lines.is_empty() {
        return Err("empty trace".to_string());
    }
    for (i, line) in lines.iter().enumerate() {
        validate_line(line).map_err(|e| format!("line {}: {e}", i + 1))?;
        let event = Json::parse(line)
            .ok()
            .and_then(|d| d.get("event").and_then(Json::as_str).map(str::to_string))
            .expect("validated line has an event");
        let is_first = i == 0;
        let is_last = i + 1 == lines.len();
        match event.as_str() {
            "run_start" if !is_first => return Err(format!("line {}: run_start not first", i + 1)),
            "run_end" if !is_last => return Err(format!("line {}: run_end not last", i + 1)),
            "iter" if is_first => return Err("line 1: trace must open with run_start".to_string()),
            _ => {}
        }
    }
    Ok(lines.len())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::span::{Phase, PhaseTimes};

    fn start_fields() -> Vec<(String, Json)> {
        vec![
            ("algo".to_string(), Json::Str("elkan".to_string())),
            ("k".to_string(), Json::Num(8.0)),
            ("n".to_string(), Json::Num(100.0)),
            ("d".to_string(), Json::Num(50.0)),
            ("threads".to_string(), Json::Num(1.0)),
        ]
    }

    fn iter_fields(i: usize, converged: bool) -> Vec<(String, Json)> {
        let mut phases = PhaseTimes::default();
        phases.add(Phase::Assignment, 1.5);
        vec![
            ("iteration".to_string(), Json::Num(i as f64)),
            ("wall_ms".to_string(), Json::Num(2.0)),
            ("elapsed_ms".to_string(), Json::Num(2.0 * (i as f64 + 1.0))),
            ("sims_point_center".to_string(), Json::Num(800.0)),
            ("reassignments".to_string(), Json::Num(10.0)),
            ("converged".to_string(), Json::Bool(converged)),
            ("phases".to_string(), phases.to_json()),
        ]
    }

    fn end_fields() -> Vec<(String, Json)> {
        vec![
            ("iterations".to_string(), Json::Num(2.0)),
            ("objective".to_string(), Json::Num(0.87)),
            ("total_ms".to_string(), Json::Num(4.1)),
            ("phases".to_string(), PhaseTimes::default().to_json()),
        ]
    }

    #[test]
    fn writer_emits_valid_jsonl() {
        let dir = std::env::temp_dir().join("sphkm-trace-test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.jsonl");
        let mut w = TraceWriter::create(&path).unwrap();
        w.record("run_start", start_fields()).unwrap();
        w.record("iter", iter_fields(0, false)).unwrap();
        w.record("iter", iter_fields(1, true)).unwrap();
        w.record("run_end", end_fields()).unwrap();
        assert_eq!(w.records(), 4);
        w.finish().unwrap();
        drop(w);
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(validate_trace(&text).unwrap(), 4);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn validator_rejects_schema_and_shape_defects() {
        assert!(validate_line("not json").is_err());
        assert!(validate_line("[1]").unwrap_err().contains("object"));
        assert!(validate_line(r#"{"event": "iter"}"#).unwrap_err().contains("schema"));
        let wrong_schema = r#"{"schema": "sphkm.trace.v0", "event": "run_end"}"#;
        assert!(validate_line(wrong_schema).unwrap_err().contains("expected"));
        let unknown_event = r#"{"schema": "sphkm.trace.v1", "event": "mystery"}"#;
        assert!(validate_line(unknown_event).unwrap_err().contains("unknown event"));
        let missing = r#"{"schema": "sphkm.trace.v1", "event": "run_start", "algo": "elkan"}"#;
        assert!(validate_line(missing).unwrap_err().contains("\"k\""));
        let bad_phase = r#"{"schema": "sphkm.trace.v1", "event": "run_end", "iterations": 1,
            "objective": 0.5, "total_ms": 1.0, "phases": {"warp_drive": 1.0}}"#
            .replace('\n', " ");
        assert!(validate_line(&bad_phase).unwrap_err().contains("warp_drive"));
    }

    #[test]
    fn trace_structure_is_enforced() {
        let start = Json::Obj(
            [
                ("schema".to_string(), Json::Str(TRACE_SCHEMA.to_string())),
                ("event".to_string(), Json::Str("run_start".to_string())),
            ]
            .into_iter()
            .chain(start_fields())
            .collect(),
        )
        .render();
        let end = Json::Obj(
            [
                ("schema".to_string(), Json::Str(TRACE_SCHEMA.to_string())),
                ("event".to_string(), Json::Str("run_end".to_string())),
            ]
            .into_iter()
            .chain(end_fields())
            .collect(),
        )
        .render();
        assert!(validate_trace("").is_err());
        assert!(validate_trace(&format!("{start}\n{end}\n")).is_ok());
        // run_start must come first, run_end last.
        assert!(validate_trace(&format!("{end}\n{start}\n")).is_err());
        assert!(validate_trace(&format!("{start}\n{start}\n{end}\n")).is_err());
    }
}
